"""End-to-end LM training driver.

Default: a ~10M-parameter llama-style model for 60 steps on CPU (finishes in
minutes and demonstrably learns the synthetic distribution).  ``--full`` runs
the ~100M-parameter configuration for 300 steps — the deliverable-scale run
(hours on this CPU container; the natural target is one TPU host).  Both paths
exercise the real trainer: sharded step builder, checkpoint/restart, seekable
data, straggler/retry logic.

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""
import argparse

from repro.configs.base import InputShape, ModelConfig, register
from repro.models import build
from repro.train.loop import LoopConfig, train


def small_cfg():
    # ~10M params
    return ModelConfig(
        name="lm-10m", family="dense", num_layers=4, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=8192,
        dtype="float32", remat=False)


def full_cfg():
    # ~100M params (GPT-2-medium-ish)
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=32768, dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    cfg = full_cfg() if args.full else small_cfg()
    steps = args.steps or (300 if args.full else 60)
    model = build(cfg)
    n_params = sum(
        int(__import__("numpy").prod(s.shape))
        for s in __import__("jax").tree.leaves(model.param_structs()))
    print(f"== {cfg.name}: {n_params/1e6:.1f}M params, {steps} steps ==")

    shape = InputShape("train", seq_len=256 if args.full else 128,
                       global_batch=8, kind="train")
    state = train(model, shape, mesh=None,
                  loop_cfg=LoopConfig(total_steps=steps, ckpt_every=max(steps // 3, 1),
                                      ckpt_dir=args.ckpt, log_every=10))
    print(f"final loss {state.losses[-1]:.4f} "
          f"(start {state.losses[0]:.4f}); "
          f"median step {sorted(state.step_times)[len(state.step_times)//2]*1e3:.0f} ms; "
          f"restarts={state.restarts} stragglers={state.straggler_events}")
    assert state.losses[-1] < state.losses[0], "did not learn"


if __name__ == "__main__":
    main()
