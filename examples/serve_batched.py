"""Batched serving with continuous batching.

    PYTHONPATH=src python examples/serve_batched.py [--arch qwen2.5-3b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=9)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_size=4, max_seq=48)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(3, 9))
        eng.submit(Request(uid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 10))))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s on CPU smoke config)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt {r.prompt.tolist()} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
