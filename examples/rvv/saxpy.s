# saxpy: y[i] += a * x[i] — a user kernel that is NOT part of the RiVec
# suite, decoded and simulated end-to-end by the RVV assembly frontend:
#
#   PYTHONPATH=src python -m repro.core.rvv examples/rvv/saxpy.s --mvl 64
#
# The .stream directives declare each array's working-set footprint (KB)
# between reuses; the analytic memory model derives miss behavior from it.
# The strip-mine loop is executed by the decoder's abstract interpreter, so
# the same file decodes to the right chunking at any hardware MVL (with an
# exact partial tail VL on the last iteration).
    .text
    .globl saxpy
    .stream x 512.0
    .stream y 512.0
saxpy:
    li      a0, 4096            # n elements (or override with --avl)
    la      a1, x
    la      a2, y
    fld     fa0, 0(sp)          # the scalar a
loop:
    vsetvli t0, a0, e64, m1, ta, ma
    vle64.v v0, (a1)
    vle64.v v1, (a2)
    vfmacc.vf v1, fa0, v0
    vse64.v v1, (a2)
    slli    t1, t0, 3
    add     a1, a1, t1
    add     a2, a2, t1
    sub     a0, a0, t0
    bnez    a0, loop
    ret
