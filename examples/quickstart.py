"""Quickstart: build an assigned architecture, train a few steps, serve it.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3-8b]

Runs the reduced (smoke) configuration on CPU; the same code drives the full
config on a TPU mesh via repro.launch.train.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models import build
from repro.serve.engine import serve_batch
from repro.train.loop import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = build(cfg)
    print(f"== {args.arch} (reduced: d={cfg.d_model}, L={cfg.num_layers}) ==")

    # --- train a few steps on the synthetic pipeline -------------------------
    shape = InputShape("quickstart", seq_len=32, global_batch=8, kind="train")
    state = train(model, shape, mesh=None,
                  loop_cfg=LoopConfig(total_steps=args.steps, ckpt_every=args.steps,
                                      ckpt_dir="/tmp/quickstart_ckpt", log_every=4))
    print(f"loss: {state.losses[0]:.3f} -> {state.losses[-1]:.3f}")

    # --- serve it -------------------------------------------------------------
    params = model.init(jax.random.key(0))
    prompts = [np.arange(6, dtype=np.int32), np.arange(10, 14, dtype=np.int32)]
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": jax.random.normal(
            jax.random.key(5), (2, cfg.num_frames, cfg.d_model))}
    if cfg.family == "vlm":
        extra = {"patches": jax.random.normal(
            jax.random.key(5), (2, cfg.num_patches, cfg.d_model))}
    outs = serve_batch(model, params, prompts, max_new_tokens=8, max_seq=32,
                       extra=extra)
    for p, o in zip(prompts, outs):
        print("prompt", p.tolist(), "->", o)


if __name__ == "__main__":
    main()
