"""The paper's §5 study: the RiVec suite on 24 vector-engine configurations.

Reproduces the shape of Figures 4-10 (speedup vs MVL x lanes per app) and the
Fig-10 LLC comparison, printed as tables.

    PYTHONPATH=src python examples/vector_engine_study.py [--app blackscholes]
"""
import argparse

from repro.core import engine as eng
from repro.core import suite, tracegen
from repro.core.characterize import characterize


def study(app: str, grid: dict):
    """Print one app's 24-config table from a batched ``sweep_all`` result."""
    print(f"\n=== {app} ({tracegen.APPS[app].notes}) ===")
    c = characterize(app, 8)
    print(f"VAO speedup {c.vao_speedup:.2f}; "
          f"%vectorization {c.pct_vectorization:.0%} @MVL=8")
    mvls = (8, 16, 32, 64, 128, 256)
    lanes = (1, 2, 4, 8)
    print("speedup over scalar     " + "".join(f"  L={l}  " for l in lanes))
    for m in mvls:
        print(f"  MVL={m:4d}            "
              + "".join(f"{grid[(m, l)]:6.2f}" for l in lanes))


def llc_study():
    print("\n=== swaptions LLC study (paper Fig 10) ===")
    mvls = (8, 64, 128, 256)
    pairs = [("swaptions", eng.VectorEngineConfig(mvl=m, lanes=8, l2_kb=l2))
             for l2 in (256, 1024) for m in mvls]
    vals = suite.speedup_batch(pairs)
    for i, l2 in enumerate((256, 1024)):
        row = vals[i * len(mvls):(i + 1) * len(mvls)]
        print(f"  L2={l2:5d}KB  " + "".join(f"{s:6.2f}" for s in row))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default=None)
    args = ap.parse_args()
    apps = [args.app] if args.app else list(tracegen.APPS)
    table = suite.sweep_all(apps)  # every app x 24 configs, batched
    for app in apps:
        study(app, table[app])
    llc_study()


if __name__ == "__main__":
    main()
