"""repro: RISC-V vector-architecture simulator + RiVec suite in JAX/Pallas.

Paper: Ramirez et al., "A RISC-V Simulator and Benchmark Suite for Designing
and Evaluating Vector Architectures", ACM TACO 17(4), 2020.
See DESIGN.md for the TPU adaptation and EXPERIMENTS.md for results.
"""
