"""Blackscholes Pallas kernel: elementwise option pricing, VMEM-tiled.

TPU adaptation of the RiVec vectorized blackscholes: the MVL sweep becomes the
block size (options per VMEM tile); the VPU executes the log/exp/erf chains
8x128 elements at a time — the analogue of the paper's pipelined vector FU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT2 = 1.4142135623730951


def _cndf(x):
    return 0.5 * (1.0 + jax.lax.erf(x / SQRT2))


def _kernel(spot_ref, strike_ref, rate_ref, vol_ref, time_ref, call_ref, o_ref):
    spot = spot_ref[...]
    strike = strike_ref[...]
    rate = rate_ref[...]
    vol = vol_ref[...]
    t = time_ref[...]
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * t) / (vol * sqrt_t)
    d2 = d1 - vol * sqrt_t
    disc = strike * jnp.exp(-rate * t)
    call = spot * _cndf(d1) - disc * _cndf(d2)
    put = disc * _cndf(-d2) - spot * _cndf(-d1)
    o_ref[...] = jnp.where(call_ref[...] != 0, call, put)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def blackscholes(spot, strike, rate, vol, time, is_call, *,
                 block: int = 2048, interpret: bool = False):
    """Inputs are flat [N] arrays (N % block == 0); is_call int32 0/1."""
    n = spot.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), spot.dtype),
        interpret=interpret,
    )(spot, strike, rate, vol, time, is_call)
