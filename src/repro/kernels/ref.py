"""Pure-jnp oracles for every Pallas kernel (the ``ref`` side of allclose tests).

These are the RiVec suite apps (paper §4) re-expressed as array programs, plus
the LM hot-spot kernels.  Each function is the semantic ground truth the
corresponding ``pallas_call`` kernel must reproduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SQRT2 = 1.4142135623730951


def _cndf(x):
    return 0.5 * (1.0 + jax.lax.erf(x / SQRT2))


def blackscholes(spot, strike, rate, vol, time, is_call):
    """Black-Scholes option pricing (PARSEC blackscholes ROI)."""
    sqrt_t = jnp.sqrt(time)
    d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * time) / (vol * sqrt_t)
    d2 = d1 - vol * sqrt_t
    call = spot * _cndf(d1) - strike * jnp.exp(-rate * time) * _cndf(d2)
    put = strike * jnp.exp(-rate * time) * _cndf(-d2) - spot * _cndf(-d1)
    return jnp.where(is_call, call, put)


def jacobi2d(a, iters=1):
    """5-point Jacobi relaxation; boundary rows/cols held fixed."""
    for _ in range(iters):
        interior = 0.2 * (a[1:-1, 1:-1] + a[1:-1, :-2] + a[1:-1, 2:]
                          + a[:-2, 1:-1] + a[2:, 1:-1])
        a = a.at[1:-1, 1:-1].set(interior)
    return a


def pathfinder(wall):
    """Rodinia pathfinder: min-cost path, row by row (dynamic programming)."""
    def row(cost, w):
        left = jnp.pad(cost[:-1], (1, 0), constant_values=jnp.inf)
        right = jnp.pad(cost[1:], (0, 1), constant_values=jnp.inf)
        return w + jnp.minimum(cost, jnp.minimum(left, right)), None
    cost, _ = jax.lax.scan(row, wall[0].astype(jnp.float32), wall[1:])
    return cost


def streamcluster_dist(points, centers):
    """Pairwise squared euclidean distances [M,D]x[N,D] -> [M,N]."""
    p2 = jnp.sum(points.astype(jnp.float32) ** 2, -1, keepdims=True)
    c2 = jnp.sum(centers.astype(jnp.float32) ** 2, -1)
    pc = points.astype(jnp.float32) @ centers.astype(jnp.float32).T
    return jnp.maximum(p2 + c2[None, :] - 2.0 * pc, 0.0)


# Moro (1995) rational approximation of the inverse cumulative normal,
# as used by PARSEC swaptions' CumNormalInv.
_MORO_A = jnp.array([2.50662823884, -18.61500062529, 41.39119773534,
                     -25.44106049637])
_MORO_B = jnp.array([-8.47351093090, 23.08336743743, -21.06224101826,
                     3.13082909833])
_MORO_C = jnp.array([0.3374754822726147, 0.9761690190917186,
                     0.1607979714918209, 0.0276438810333863,
                     0.0038405729373609, 0.0003951896511919,
                     0.0000321767881768, 0.0000002888167364,
                     0.0000003960315187])


def cum_normal_inv(u):
    """Swaptions CumNormalInv (Moro's algorithm)."""
    x = u - 0.5
    r_c = x * x
    num = x * (_MORO_A[0] + r_c * (_MORO_A[1] + r_c * (_MORO_A[2] + r_c * _MORO_A[3])))
    den = 1.0 + r_c * (_MORO_B[0] + r_c * (_MORO_B[1] + r_c * (_MORO_B[2] + r_c * _MORO_B[3])))
    central = num / den
    rr = jnp.where(x > 0, 1.0 - u, u)
    rr = jnp.clip(rr, 1e-12, 0.5)
    z = jnp.log(-jnp.log(rr))
    tail = (_MORO_C[0] + z * (_MORO_C[1] + z * (_MORO_C[2] + z * (_MORO_C[3]
            + z * (_MORO_C[4] + z * (_MORO_C[5] + z * (_MORO_C[6]
            + z * (_MORO_C[7] + z * _MORO_C[8]))))))))
    tail = jnp.where(x > 0, tail, -tail)
    return jnp.where(jnp.abs(x) < 0.42, central, tail)


def canneal_swap_cost(locs, fan_idx, cand_a, cand_b):
    """Canneal swap_cost: manhattan routing cost of each element's fan
    against two candidate locations.

    locs [N,2]; fan_idx [B,F] (entries -1 = padding); cand_a/b [B,2].
    Returns (cost_a [B], cost_b [B]).
    """
    valid = fan_idx >= 0
    fl = locs[jnp.maximum(fan_idx, 0)].astype(jnp.float32)       # [B,F,2]
    da = jnp.abs(fl - cand_a[:, None, :].astype(jnp.float32)).sum(-1)
    db = jnp.abs(fl - cand_b[:, None, :].astype(jnp.float32)).sum(-1)
    va = jnp.where(valid, da, 0.0).sum(-1)
    vb = jnp.where(valid, db, 0.0).sum(-1)
    return va, vb


def particlefilter_findindex(cdf, u):
    """Rodinia particle filter guess-update: for each u_j, the first index i
    with cdf[i] >= u_j (the vfirst.m/vpopc.m pattern)."""
    counts = jnp.sum(cdf[None, :] < u[:, None], axis=1)
    return jnp.minimum(counts, cdf.shape[0] - 1).astype(jnp.int32)


def flash_attention(q, k, v, causal=True):
    """Exact softmax attention. q/k/v [B,S,H,D] -> [B,S,H,D]."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", a.astype(q.dtype), v)


def decode_attention(q, k, v, kv_len):
    """Single-token attention vs cache. q [B,H,D], k/v [B,S,H,D], kv_len int."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhd,bkhd->bhk", q, k).astype(jnp.float32) * scale
    mask = jnp.arange(k.shape[1]) < kv_len
    s = jnp.where(mask[None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", a.astype(q.dtype), v)


def ssd_scan(x, dt, A, B, C, chunk):
    """Mamba-2 SSD reference (same math as models/ssm._ssd_chunked).

    x [b,S,H,P]; dt [b,S,H]; A [H]; B/C [b,S,N] -> y [b,S,H,P]."""
    from repro.models.ssm import _ssd_chunked
    y, _ = _ssd_chunked(x, dt, A, B, C, jnp.zeros(x.shape[2]), chunk)
    return y
