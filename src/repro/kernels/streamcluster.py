"""Streamcluster dist Pallas kernel: pairwise squared distances on the MXU.

Hardware adaptation (DESIGN.md §2): the paper's dist() is a dot-product-shaped
loop (1 load + 1 multiply-sub per chunk, then a reduction), i.e. bandwidth
bound on a vector machine.  On TPU we rewrite ||p-c||^2 = ||p||^2 + ||c||^2
- 2 p.c so the O(M*N*D) term runs on the MXU systolic array instead of the
VPU — the single biggest structural win available to this app.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, c_ref, o_ref):
    p = p_ref[...].astype(jnp.float32)       # [BM, D]
    c = c_ref[...].astype(jnp.float32)       # [BN, D]
    p2 = jnp.sum(p * p, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    pc = jax.lax.dot_general(p, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(p2 + c2[None, :] - 2.0 * pc, 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def streamcluster_dist(points, centers, *, bm: int = 256, bn: int = 256,
                       interpret: bool = False):
    """points [M,D], centers [N,D] -> squared distances [M,N] (fp32)."""
    M, D = points.shape
    N, _ = centers.shape
    bm, bn = min(bm, M), min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    return pl.pallas_call(
        _kernel,
        grid=(M // bm, N // bn),
        in_specs=[pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, D), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(points, centers)
