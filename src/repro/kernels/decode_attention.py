"""Flash-decode Pallas kernel: one query token vs a long KV cache.

Grid over KV blocks with online-softmax scratch; the valid-length mask makes
it usable against partially-filled caches.  This is the per-shard compute of
distributed/collectives.flash_decode_attention, moved from XLA into VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref, *, bk):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # [H, D]
    k = k_ref[0].astype(jnp.float32)          # [bk, H, D]
    v = v_ref[0].astype(jnp.float32)
    kv_len = len_ref[0]
    s = jnp.einsum("hd,khd->hk", q, k) * (q.shape[-1] ** -0.5)
    ki = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(ki < kv_len, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.einsum("hk,khd->hd", p, v)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, kv_len, *, bk: int = 1024, interpret: bool = False):
    """q [B,H,D]; k/v [B,S,H,D]; kv_len int32 [B] -> out [B,H,D]."""
    B, S, H, D = k.shape
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=(B, S // bk),
        in_specs=[pl.BlockSpec((1, H, D), lambda b, j: (b, 0, 0)),
                  pl.BlockSpec((1, bk, H, D), lambda b, j: (b, j, 0, 0)),
                  pl.BlockSpec((1, bk, H, D), lambda b, j: (b, j, 0, 0)),
                  pl.BlockSpec((1,), lambda b, j: (b,))],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((H, D), jnp.float32),
                        pltpu.VMEM((H,), jnp.float32),
                        pltpu.VMEM((H,), jnp.float32)],
        interpret=interpret,
    )(q, k, v, lens)
