"""Jacobi-2D stencil Pallas kernel: halo'd row-strip tiling.

The paper's vslide1up/vslide1down (lane-interconnect traffic) becomes
intra-VREG column shifts; the vertical neighbors come from a one-row halo on
each strip.  The wrapper materializes overlapping strips (the TPU equivalent
of a halo exchange) and the kernel updates each strip's interior.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, o_ref):
    a = a_ref[0]                      # [R+2, C]
    center = a[1:-1, :]
    up = a[:-2, :]
    down = a[2:, :]
    left = jnp.roll(center, 1, axis=1)    # slide1up along the lane dim
    right = jnp.roll(center, -1, axis=1)  # slide1down
    out = 0.2 * (center + up + down + left + right)
    # boundary columns keep their original values
    cols = jax.lax.broadcasted_iota(jnp.int32, out.shape, 1)
    out = jnp.where((cols == 0) | (cols == out.shape[1] - 1), center, out)
    o_ref[0] = out


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def jacobi2d_step(a, *, rows_per_block: int = 64, interpret: bool = False):
    """One Jacobi sweep over a [R, C] grid (R-2 interior rows updated).

    (R-2) % rows_per_block must be 0.
    """
    R, C = a.shape
    interior = R - 2
    assert interior % rows_per_block == 0, (R, rows_per_block)
    nb = interior // rows_per_block
    # overlapping strips [nb, rows+2, C] — halo materialization
    idx = (jnp.arange(nb)[:, None] * rows_per_block
           + jnp.arange(rows_per_block + 2)[None, :])
    strips = a[idx]                    # [nb, rows+2, C]
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, rows_per_block + 2, C), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, rows_per_block, C), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, rows_per_block, C), a.dtype),
        interpret=interpret,
    )(strips)
    return a.at[1:-1].set(out.reshape(interior, C))
