"""Canneal swap_cost Pallas kernel: VMEM-resident location table + gather.

The paper's indexed loads (the app's bottleneck on a vector machine) become a
gather from a VMEM-resident coordinate table: the table block stays pinned
while fan-index blocks stream through — the TPU analogue of keeping the hot
data behind the VMU.  Padding entries (fan_idx < 0) are masked, reproducing
the paper's short-and-variable VL behavior.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(locs_ref, idx_ref, ca_ref, cb_ref, oa_ref, ob_ref):
    locs = locs_ref[...].astype(jnp.float32)       # [N, 2] (VMEM resident)
    idx = idx_ref[...]                             # [B, F]
    valid = idx >= 0
    safe = jnp.maximum(idx, 0)
    fx = locs[:, 0][safe]                          # gather (indexed load)
    fy = locs[:, 1][safe]
    ca = ca_ref[...].astype(jnp.float32)           # [B, 2]
    cb = cb_ref[...].astype(jnp.float32)
    da = jnp.abs(fx - ca[:, 0:1]) + jnp.abs(fy - ca[:, 1:2])
    db = jnp.abs(fx - cb[:, 0:1]) + jnp.abs(fy - cb[:, 1:2])
    oa_ref[...] = jnp.where(valid, da, 0.0).sum(-1)   # the vredsum
    ob_ref[...] = jnp.where(valid, db, 0.0).sum(-1)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def swap_cost(locs, fan_idx, cand_a, cand_b, *, block: int = 256,
              interpret: bool = False):
    """locs [N,2]; fan_idx [B,F] (-1 padded); cand_a/b [B,2] -> ([B],[B])."""
    N = locs.shape[0]
    B, F = fan_idx.shape
    assert B % block == 0, (B, block)
    grid = (B // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((N, 2), lambda i: (0, 0)),
                  pl.BlockSpec((block, F), lambda i: (i, 0)),
                  pl.BlockSpec((block, 2), lambda i: (i, 0)),
                  pl.BlockSpec((block, 2), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((B,), jnp.float32),
                   jax.ShapeDtypeStruct((B,), jnp.float32)],
        interpret=interpret,
    )(locs, fan_idx, cand_a, cand_b)
