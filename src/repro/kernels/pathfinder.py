"""Pathfinder Pallas kernel: dynamic-programming row sweep with VMEM scratch.

The running min-cost row lives in VMEM scratch and persists across the
sequential TPU grid (one grid step per wall row) — the decoupled-engine
analogue of keeping the working vector register resident.  slide1up/slide1down
become +-1 column shifts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INF = 3.0e38  # python scalar: jnp constants would be captured consts in the kernel


def _kernel(wall_ref, o_ref, cost_ref):
    i = pl.program_id(0)
    nrows = pl.num_programs(0)
    w = wall_ref[0].astype(jnp.float32)      # [C]

    @pl.when(i == 0)
    def _init():
        cost_ref[...] = w

    @pl.when(i > 0)
    def _step():
        cost = cost_ref[...]
        c = cost.reshape(1, -1)
        left = jnp.roll(c, 1, axis=1).at[:, 0].set(_INF)[0]    # slide1up
        right = jnp.roll(c, -1, axis=1).at[:, -1].set(_INF)[0]  # slide1down
        cost_ref[...] = w + jnp.minimum(cost, jnp.minimum(left, right))

    @pl.when(i == nrows - 1)
    def _emit():
        o_ref[0] = cost_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def pathfinder(wall, *, interpret: bool = False):
    """wall [R, C] -> final min-cost row [C] (fp32)."""
    R, C = wall.shape
    out = pl.pallas_call(
        _kernel,
        grid=(R,),
        in_specs=[pl.BlockSpec((1, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, C), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((C,), jnp.float32)],
        interpret=interpret,
    )(wall)
    return out[0]
