"""Mamba-2 SSD chunk-scan Pallas kernel.

Grid = (batch, chunks); the recurrent state [H, P, N] lives in VMEM scratch
and persists across the sequential chunk dimension — the chunk length is the
TPU analogue of the paper's MVL.  Per chunk: an intra-chunk quadratic block
(two MXU dots through the decay matrix) plus a rank-Q state update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, Q):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, H, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q, H]
    A = a_ref[...].astype(jnp.float32)        # [H]
    Bm = b_ref[0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)         # [Q, N]

    dA = dt * A[None, :]                      # [Q, H] (negative)
    xd = x * dt[..., None]                    # [Q, H, P]
    seg = jnp.cumsum(dA, axis=0)              # [Q, H]

    # intra-chunk: y[t] = sum_{u<=t} (C_t.B_u) exp(seg_t - seg_u) x_u dt_u
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    diff = seg[:, None, :] - seg[None, :, :]  # [Q, Q, H]
    decay = jnp.where(tri[..., None], jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    w = cb[..., None] * decay                 # [Q, Q, H]
    y = jnp.einsum("tuh,uhp->thp", w, xd)

    # carried-in state contribution + state update
    state = state_ref[...]                    # [H, P, N]
    y = y + jnp.einsum("tn,hpn,th->thp", Cm, state, jnp.exp(seg))
    tot = seg[-1]                             # [H]
    sdecay = jnp.exp(tot[None, :] - seg)      # [Q, H]
    state_ref[...] = (jnp.exp(tot)[:, None, None] * state
                      + jnp.einsum("un,uhp,uh->hpn", Bm, xd, sdecay))
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = False):
    """x [b,S,H,P]; dt [b,S,H]; A [H]; B/C [b,S,N] -> y [b,S,H,P].

    (D-skip and gating stay outside the kernel.)  S % chunk == 0.
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    out = pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=(b, nc),
        in_specs=[pl.BlockSpec((1, Q, H, P), lambda i, j: (i, j, 0, 0)),
                  pl.BlockSpec((1, Q, H), lambda i, j: (i, j, 0)),
                  pl.BlockSpec((H,), lambda i, j: (0,)),
                  pl.BlockSpec((1, Q, N), lambda i, j: (i, j, 0)),
                  pl.BlockSpec((1, Q, N), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, Q, H, P), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return out
