"""Particle-filter find-index Pallas kernel: the vfirst.m / vpopc.m pattern.

For each query u_j over a monotone CDF, the first index with cdf[i] >= u_j
equals popcount(cdf < u_j) — the paper's mask-to-scalar instructions become a
compare + intra-block reduction, accumulated across CDF blocks in the
sequential grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cdf_ref, u_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cdf = cdf_ref[...]                      # [BC]
    u = u_ref[...]                          # [BU]
    counts = jnp.sum((cdf[None, :] < u[:, None]).astype(jnp.int32), axis=1)
    o_ref[...] += counts                    # vpopc.m accumulation


@functools.partial(jax.jit, static_argnames=("bu", "bc", "interpret"))
def find_index(cdf, u, *, bu: int = 256, bc: int = 2048, interpret: bool = False):
    """cdf [N] monotone; u [M] queries -> first index [M] with cdf >= u."""
    N, M = cdf.shape[0], u.shape[0]
    bu, bc = min(bu, M), min(bc, N)
    assert M % bu == 0 and N % bc == 0, (M, N, bu, bc)
    counts = pl.pallas_call(
        _kernel,
        grid=(M // bu, N // bc),
        in_specs=[pl.BlockSpec((bc,), lambda i, j: (j,)),
                  pl.BlockSpec((bu,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bu,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((M,), jnp.int32),
        interpret=interpret,
    )(cdf, u)
    return jnp.minimum(counts, N - 1)
