"""Swaptions CumNormalInv Pallas kernel (Moro 1995 inverse normal CDF).

The HJM Monte-Carlo's hottest elementwise chain (paper §4.1.7): a rational
polynomial for the central region and a log-log polynomial tail, fused into
one VMEM-tiled pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Moro coefficients as python floats (jnp module constants would be captured
# consts inside the kernel, which pallas rejects)
_A = (2.50662823884, -18.61500062529, 41.39119773534, -25.44106049637)
_B = (-8.47351093090, 23.08336743743, -21.06224101826, 3.13082909833)
_C = (0.3374754822726147, 0.9761690190917186, 0.1607979714918209,
      0.0276438810333863, 0.0038405729373609, 0.0003951896511919,
      0.0000321767881768, 0.0000002888167364, 0.0000003960315187)


def _kernel(u_ref, o_ref):
    u = u_ref[...]
    x = u - 0.5
    r = x * x
    num = x * (_A[0] + r * (_A[1] + r * (_A[2] + r * _A[3])))
    den = 1.0 + r * (_B[0] + r * (_B[1] + r * (_B[2] + r * _B[3])))
    central = num / den
    rr = jnp.where(x > 0, 1.0 - u, u)
    rr = jnp.clip(rr, 1e-12, 0.5)
    z = jnp.log(-jnp.log(rr))
    tail = _C[8]
    for c in reversed(_C[:8]):
        tail = c + z * tail
    tail = jnp.where(x > 0, tail, -tail)
    o_ref[...] = jnp.where(jnp.abs(x) < 0.42, central, tail)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def cum_normal_inv(u, *, block: int = 2048, interpret: bool = False):
    """u flat [N] uniforms in (0,1); N % block == 0."""
    n = u.shape[0]
    assert n % block == 0, (n, block)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), u.dtype),
        interpret=interpret,
    )(u)
