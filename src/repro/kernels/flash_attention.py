"""Causal flash attention Pallas kernel (fwd): online softmax, VMEM tiles.

The hillclimbed replacement for models/layers._chunked_attn: scores never
leave VMEM (the XLA baseline spills [Sq, ck]-sized f32 tensors to HBM — the
dominant memory-roofline term measured in the dry-run).  Block shapes are
MXU-aligned (multiples of 128 on the contracted dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, bq, bk, causal):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _block():
        q = q_ref[0].astype(jnp.float32)       # [bq, d]
        k = k_ref[0].astype(jnp.float32)       # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (q.shape[-1] ** -0.5)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        # whole blocks above the diagonal are skipped (block-sparse causal)
        pl.when(kj * bk <= qi * bq + bq - 1)(_block)
    else:
        _block()

    @pl.when(kj == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention(q, k, v, *, bq: int = 512, bk: int = 512,
                    causal: bool = True, interpret: bool = False):
    """q/k/v [B,S,H,D] -> [B,S,H,D].  S % bq == S % bk == 0."""
    B, S, H, D = q.shape
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    # fold batch x heads into the leading grid dim
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal),
        grid=(B * H, S // bq, S // bk),
        in_specs=[pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
