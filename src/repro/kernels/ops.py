"""Public jit'd wrappers for every Pallas kernel.

``interpret`` defaults to True off-TPU so the whole suite runs (and is tested)
on CPU; on a real TPU backend the kernels compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels import (blackscholes as _bs, canneal as _ca,
                           decode_attention as _da, flash_attention as _fa,
                           jacobi2d as _j2, particlefilter as _pf,
                           pathfinder as _path, ssd_scan as _ssd,
                           streamcluster as _sc, swaptions as _sw)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def blackscholes(spot, strike, rate, vol, time, is_call, *, block=2048,
                 interpret=None):
    return _bs.blackscholes(spot, strike, rate, vol, time, is_call,
                            block=block,
                            interpret=_interpret_default() if interpret is None else interpret)


def jacobi2d_step(a, *, rows_per_block=64, interpret=None):
    return _j2.jacobi2d_step(a, rows_per_block=rows_per_block,
                             interpret=_interpret_default() if interpret is None else interpret)


def pathfinder(wall, *, interpret=None):
    return _path.pathfinder(
        wall, interpret=_interpret_default() if interpret is None else interpret)


def streamcluster_dist(points, centers, *, bm=256, bn=256, interpret=None):
    return _sc.streamcluster_dist(
        points, centers, bm=bm, bn=bn,
        interpret=_interpret_default() if interpret is None else interpret)


def cum_normal_inv(u, *, block=2048, interpret=None):
    return _sw.cum_normal_inv(
        u, block=block,
        interpret=_interpret_default() if interpret is None else interpret)


def canneal_swap_cost(locs, fan_idx, cand_a, cand_b, *, block=256, interpret=None):
    return _ca.swap_cost(
        locs, fan_idx, cand_a, cand_b, block=block,
        interpret=_interpret_default() if interpret is None else interpret)


def particlefilter_findindex(cdf, u, *, bu=256, bc=2048, interpret=None):
    return _pf.find_index(
        cdf, u, bu=bu, bc=bc,
        interpret=_interpret_default() if interpret is None else interpret)


def flash_attention(q, k, v, *, bq=512, bk=512, causal=True, interpret=None):
    return _fa.flash_attention(
        q, k, v, bq=bq, bk=bk, causal=causal,
        interpret=_interpret_default() if interpret is None else interpret)


def decode_attention(q, k, v, kv_len, *, bk=1024, interpret=None):
    return _da.decode_attention(
        q, k, v, kv_len, bk=bk,
        interpret=_interpret_default() if interpret is None else interpret)


def ssd_scan(x, dt, A, B, C, *, chunk=256, interpret=None):
    return _ssd.ssd_scan(
        x, dt, A, B, C, chunk=chunk,
        interpret=_interpret_default() if interpret is None else interpret)
