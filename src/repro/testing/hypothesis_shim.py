"""Minimal stand-in for ``hypothesis`` so property tests still run (as
seeded random sampling) when the real library is not installed.

Covers exactly the API surface the test suite uses::

    from repro.testing.hypothesis_shim import given, settings, strategies

``strategies`` provides ``builds``, ``sampled_from``, ``booleans`` and
``integers``; ``given`` draws ``max_examples`` deterministic examples
(seeded RNG, so failures reproduce); ``settings`` records ``max_examples``
and ignores everything else.  Install the real ``hypothesis``
(requirements-dev.txt) for shrinking and adversarial example search.
"""
from __future__ import annotations

import functools
import random
import sys


class Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)


def sampled_from(elements) -> Strategy:
    elements = list(elements)
    return Strategy(lambda rng: elements[rng.randrange(len(elements))])


def booleans() -> Strategy:
    return sampled_from([False, True])


def integers(min_value: int = 0, max_value: int = 2 ** 31 - 1) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0) -> Strategy:
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def builds(target, **kwargs) -> Strategy:
    return Strategy(lambda rng: target(
        **{k: s.example(rng) for k, s in kwargs.items()}))


def settings(max_examples: int = 10, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies_args):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 10))
            rng = random.Random(12345)
            for _ in range(n):
                drawn = [s.example(rng) for s in strategies_args]
                fn(*args, *drawn, **kwargs)
        # pytest must see the zero-arg wrapper signature, not the wrapped
        # test's (strategy-filled) parameters — else it hunts for fixtures.
        del wrapper.__wrapped__
        return wrapper
    return deco


# mirror `from hypothesis import strategies as st`
strategies = sys.modules[__name__]
