# particlefilter: RVV v1.0 kernel emitted by repro.core.codegen -- do not edit.
# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at
# every effective MVL in {8/16/32/64/128/256}; the .chunk loop's bgtz
# counter encodes the exact fractional trip count.
    .text
    .globl particlefilter
    .stream fp0 781.0
particlefilter:
    vsetvli t0, zero, e64, m1
    vmv.v.i v0, 0
    vcpop.m s3, v0
    li t1, 8
    beq t0, t1, cfg_8
    li t1, 16
    beq t0, t1, cfg_16
    li t1, 32
    beq t0, t1, cfg_32
    li t1, 64
    beq t0, t1, cfg_64
    li t1, 128
    beq t0, t1, cfg_128
    li t1, 256
    beq t0, t1, cfg_256
    j vl_bad
cfg_8:
    li a3, 3455848845218065
    li a4, 2147483648
    j cfg_done
cfg_16:
    li a3, 3455848845218065
    li a4, 4294967296
    j cfg_done
cfg_32:
    li a3, 3455848845218065
    li a4, 8589934592
    j cfg_done
cfg_64:
    li a3, 3455848845218065
    li a4, 17179869184
    j cfg_done
cfg_128:
    li a3, 3455848845218065
    li a4, 34359738368
    j cfg_done
cfg_256:
    li a3, 3455848845218065
    li a4, 68719476736
    j cfg_done
vl_bad:
    call abort
cfg_done:
    .chunk
loop:
    li t1, 8
    beq t0, t1, body_8
    li t1, 16
    beq t0, t1, body_16
    li t1, 32
    beq t0, t1, body_32
    li t1, 64
    beq t0, t1, body_64
    li t1, 128
    beq t0, t1, body_128
    li t1, 256
    beq t0, t1, body_256
    j vl_bad
body_8:
    la a5, fp0
    vle64.v v0, (a5)
    vfexp.v v0, ft0
    vfmul.vf v1, ft0, ft1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfdiv.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfdiv.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v2, v3, v9
    vfexp.v v3, v4
    vfadd.vv v4, v5, v0
    vfadd.vv v1, v6, v1
    vfexp.v v1, v7
    vfadd.vv v1, v8, v2
    vfdiv.vv v1, v9, v3
    vfmul.vv v1, v10, v4
    vfexp.v v0, v0
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    j close
body_16:
    la a5, fp0
    vle64.v v0, (a5)
    vfexp.v v0, ft0
    vfmul.vf v1, ft0, ft1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfdiv.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfdiv.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v2, v3, v9
    vfexp.v v3, v4
    vfadd.vv v4, v5, v0
    vfadd.vv v1, v6, v1
    vfexp.v v1, v7
    vfadd.vv v1, v8, v2
    vfdiv.vv v1, v9, v3
    vfmul.vv v1, v10, v4
    vfexp.v v0, v0
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    j close
body_32:
    la a5, fp0
    vle64.v v0, (a5)
    vfexp.v v0, ft0
    vfmul.vf v1, ft0, ft1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfdiv.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfdiv.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v2, v3, v9
    vfexp.v v3, v4
    vfadd.vv v4, v5, v0
    vfadd.vv v1, v6, v1
    vfexp.v v1, v7
    vfadd.vv v1, v8, v2
    vfdiv.vv v1, v9, v3
    vfmul.vv v1, v10, v4
    vfexp.v v0, v0
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    j close
body_64:
    la a5, fp0
    vle64.v v0, (a5)
    vfexp.v v0, ft0
    vfmul.vf v1, ft0, ft1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfdiv.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfdiv.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v2, v3, v9
    vfexp.v v3, v4
    vfadd.vv v4, v5, v0
    vfadd.vv v1, v6, v1
    vfexp.v v1, v7
    vfadd.vv v1, v8, v2
    vfdiv.vv v1, v9, v3
    vfmul.vv v1, v10, v4
    vfexp.v v0, v0
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    j close
body_128:
    la a5, fp0
    vle64.v v0, (a5)
    vfexp.v v0, ft0
    vfmul.vf v1, ft0, ft1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfdiv.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfdiv.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v2, v3, v9
    vfexp.v v3, v4
    vfadd.vv v4, v5, v0
    vfadd.vv v1, v6, v1
    vfexp.v v1, v7
    vfadd.vv v1, v8, v2
    vfdiv.vv v1, v9, v3
    vfmul.vv v1, v10, v4
    vfexp.v v0, v0
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    j close
body_256:
    la a5, fp0
    vle64.v v0, (a5)
    vfexp.v v0, ft0
    vfmul.vf v1, ft0, ft1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfexp.v v3, v3
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfexp.v v0, v0
    vfadd.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfdiv.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfexp.v v6, v6
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfexp.v v0, v0
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfexp.v v1, v1
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfdiv.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfexp.v v10, v10
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfdiv.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfexp.v v3, v3
    vfdiv.vv v4, v4, v10
    vfdiv.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfdiv.vv v2, v2, v8
    vfexp.v v3, v3
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfexp.v v6, v6
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfdiv.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfexp.v v8, v8
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfexp.v v5, v5
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfdiv.vv v8, v8, v3
    vfexp.v v9, v9
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfexp.v v3, v3
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfexp.v v8, v8
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfexp.v v1, v1
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v2, v3, v9
    vfexp.v v3, v4
    vfadd.vv v4, v5, v0
    vfadd.vv v1, v6, v1
    vfexp.v v1, v7
    vfadd.vv v1, v8, v2
    vfdiv.vv v1, v9, v3
    vfmul.vv v1, v10, v4
    vfexp.v v0, v0
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v0, v0, ft0
    vfadd.vf v1, v1, ft0
    vfadd.vf v1, v2, ft0
    vfadd.vf v1, v3, ft0
    vfadd.vf v1, v4, ft0
    vfadd.vf v0, v0, ft0
    vcpop.m t6, v5
    vcpop.m t6, v6
    .rept 84
    add s4, s5, s3
    .endr
    j close
close:
    sub a3, a3, a4
    bgtz a3, loop
    ret
