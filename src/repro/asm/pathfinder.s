# pathfinder: RVV v1.0 kernel emitted by repro.core.codegen -- do not edit.
# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at
# every effective MVL in {8/16/32/64/128/256}; the .chunk loop's bgtz
# counter encodes the exact fractional trip count.
    .text
    .globl pathfinder
    .stream fp0 1253376.0
    .stream fp1 781.25
pathfinder:
    vsetvli t0, zero, e64, m1
    li t1, 8
    beq t0, t1, cfg_8
    li t1, 16
    beq t0, t1, cfg_16
    li t1, 32
    beq t0, t1, cfg_32
    li t1, 64
    beq t0, t1, cfg_64
    li t1, 128
    beq t0, t1, cfg_128
    li t1, 256
    beq t0, t1, cfg_256
    j vl_bad
cfg_8:
    li a3, 20054016
    li a4, 1
    j cfg_done
cfg_16:
    li a3, 10027008
    li a4, 1
    j cfg_done
cfg_32:
    li a3, 5013504
    li a4, 1
    j cfg_done
cfg_64:
    li a3, 2506752
    li a4, 1
    j cfg_done
cfg_128:
    li a3, 1253376
    li a4, 1
    j cfg_done
cfg_256:
    li a3, 626688
    li a4, 1
    j cfg_done
vl_bad:
    call abort
cfg_done:
    .chunk
loop:
    li t1, 8
    beq t0, t1, body_8
    li t1, 16
    beq t0, t1, body_16
    li t1, 32
    beq t0, t1, body_32
    li t1, 64
    beq t0, t1, body_64
    li t1, 128
    beq t0, t1, body_128
    li t1, 256
    beq t0, t1, body_256
    j vl_bad
body_8:
    .rept 38
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vle64.v v2, (a5)
    vslide1down.vx v3, v1, t5
    vslide1down.vx v4, v1, t5
    vfadd.vv v1, v3, v1
    vfadd.vv v1, v1, v4
    vfadd.vv v0, v1, v0
    vfadd.vv v0, v0, v2
    vslide1down.vx v1, v0, t5
    vslide1down.vx v2, v0, t5
    vfadd.vv v1, v1, v2
    vfadd.vv v0, v1, v0
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vse64.v v0, (a5)
    j close
body_16:
    .rept 38
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vle64.v v2, (a5)
    vslide1down.vx v3, v1, t5
    vslide1down.vx v4, v1, t5
    vfadd.vv v1, v3, v1
    vfadd.vv v1, v1, v4
    vfadd.vv v0, v1, v0
    vfadd.vv v0, v0, v2
    vslide1down.vx v1, v0, t5
    vslide1down.vx v2, v0, t5
    vfadd.vv v1, v1, v2
    vfadd.vv v0, v1, v0
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vse64.v v0, (a5)
    j close
body_32:
    .rept 38
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vle64.v v2, (a5)
    vslide1down.vx v3, v1, t5
    vslide1down.vx v4, v1, t5
    vfadd.vv v1, v3, v1
    vfadd.vv v1, v1, v4
    vfadd.vv v0, v1, v0
    vfadd.vv v0, v0, v2
    vslide1down.vx v1, v0, t5
    vslide1down.vx v2, v0, t5
    vfadd.vv v1, v1, v2
    vfadd.vv v0, v1, v0
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vse64.v v0, (a5)
    j close
body_64:
    .rept 38
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vle64.v v2, (a5)
    vslide1down.vx v3, v1, t5
    vslide1down.vx v4, v1, t5
    vfadd.vv v1, v3, v1
    vfadd.vv v1, v1, v4
    vfadd.vv v0, v1, v0
    vfadd.vv v0, v0, v2
    vslide1down.vx v1, v0, t5
    vslide1down.vx v2, v0, t5
    vfadd.vv v1, v1, v2
    vfadd.vv v0, v1, v0
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vse64.v v0, (a5)
    j close
body_128:
    .rept 38
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vle64.v v2, (a5)
    vslide1down.vx v3, v1, t5
    vslide1down.vx v4, v1, t5
    vfadd.vv v1, v3, v1
    vfadd.vv v1, v1, v4
    vfadd.vv v0, v1, v0
    vfadd.vv v0, v0, v2
    vslide1down.vx v1, v0, t5
    vslide1down.vx v2, v0, t5
    vfadd.vv v1, v1, v2
    vfadd.vv v0, v1, v0
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vse64.v v0, (a5)
    j close
body_256:
    .rept 38
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vle64.v v2, (a5)
    vslide1down.vx v3, v1, t5
    vslide1down.vx v4, v1, t5
    vfadd.vv v1, v3, v1
    vfadd.vv v1, v1, v4
    vfadd.vv v0, v1, v0
    vfadd.vv v0, v0, v2
    vslide1down.vx v1, v0, t5
    vslide1down.vx v2, v0, t5
    vfadd.vv v1, v1, v2
    vfadd.vv v0, v1, v0
    la a5, fp1
    vle64.v v1, (a5)
    la a5, fp1
    vse64.v v0, (a5)
    j close
close:
    sub a3, a3, a4
    bgtz a3, loop
    ret
