# swaptions: RVV v1.0 kernel emitted by repro.core.codegen -- do not edit.
# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at
# every effective MVL in {8/16/32/64/128/256}; the .chunk loop's bgtz
# counter encodes the exact fractional trip count.
    .text
    .globl swaptions
    .stream fp0 21.875
    .stream fp1 43.75
    .stream fp2 87.5
    .stream fp3 175.0
    .stream fp4 350.0
    .stream fp5 700.0
swaptions:
    vsetvli t0, zero, e64, m1
    li t1, 8
    beq t0, t1, cfg_8
    li t1, 16
    beq t0, t1, cfg_16
    li t1, 32
    beq t0, t1, cfg_32
    li t1, 64
    beq t0, t1, cfg_64
    li t1, 128
    beq t0, t1, cfg_128
    li t1, 256
    beq t0, t1, cfg_256
    j vl_bad
cfg_8:
    li a3, 1252094932138337
    li a4, 16777216
    j cfg_done
cfg_16:
    li a3, 1252094932138337
    li a4, 33554432
    j cfg_done
cfg_32:
    li a3, 1252094932138337
    li a4, 67108864
    j cfg_done
cfg_64:
    li a3, 1252094932138337
    li a4, 134217728
    j cfg_done
cfg_128:
    li a3, 1252094932138337
    li a4, 268435456
    j cfg_done
cfg_256:
    li a3, 1252094932138337
    li a4, 536870912
    j cfg_done
vl_bad:
    call abort
cfg_done:
    .chunk
loop:
    li t1, 8
    beq t0, t1, body_8
    li t1, 16
    beq t0, t1, body_16
    li t1, 32
    beq t0, t1, body_32
    li t1, 64
    beq t0, t1, body_64
    li t1, 128
    beq t0, t1, body_128
    li t1, 256
    beq t0, t1, body_256
    j vl_bad
body_8:
    .rept 52
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    vid.v v0
    vid.v v1
    vfexp.v v2, ft0
    vfmul.vf v3, v0, ft0
    vfmul.vf v4, v1, ft0
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfexp.v v2, v4
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfadd.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfmul.vv v0, v2, v0
    vfadd.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfdiv.vv v1, v3, v1
    vfmul.vv v1, v4, v2
    vfadd.vv v0, v0, v3
    la a5, fp0
    vse64.v v1, (a5)
    j close
body_16:
    .rept 52
    add s5, s5, s6
    .endr
    la a5, fp1
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v0, (a5)
    la a5, fp1
    vle64.v v0, (a5)
    vid.v v0
    vid.v v1
    vfexp.v v2, ft0
    vfmul.vf v3, v0, ft0
    vfmul.vf v4, v1, ft0
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfexp.v v2, v4
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfadd.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfmul.vv v0, v2, v0
    vfadd.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfdiv.vv v1, v3, v1
    vfmul.vv v1, v4, v2
    vfadd.vv v0, v0, v3
    la a5, fp1
    vse64.v v1, (a5)
    j close
body_32:
    .rept 52
    add s5, s5, s6
    .endr
    la a5, fp2
    vle64.v v0, (a5)
    la a5, fp2
    vle64.v v0, (a5)
    la a5, fp2
    vle64.v v0, (a5)
    la a5, fp2
    vle64.v v0, (a5)
    vid.v v0
    vid.v v1
    vfexp.v v2, ft0
    vfmul.vf v3, v0, ft0
    vfmul.vf v4, v1, ft0
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfexp.v v2, v4
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfadd.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfmul.vv v0, v2, v0
    vfadd.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfdiv.vv v1, v3, v1
    vfmul.vv v1, v4, v2
    vfadd.vv v0, v0, v3
    la a5, fp2
    vse64.v v1, (a5)
    j close
body_64:
    .rept 52
    add s5, s5, s6
    .endr
    la a5, fp3
    vle64.v v0, (a5)
    la a5, fp3
    vle64.v v0, (a5)
    la a5, fp3
    vle64.v v0, (a5)
    la a5, fp3
    vle64.v v0, (a5)
    vid.v v0
    vid.v v1
    vfexp.v v2, ft0
    vfmul.vf v3, v0, ft0
    vfmul.vf v4, v1, ft0
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfexp.v v2, v4
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfadd.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfmul.vv v0, v2, v0
    vfadd.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfdiv.vv v1, v3, v1
    vfmul.vv v1, v4, v2
    vfadd.vv v0, v0, v3
    la a5, fp3
    vse64.v v1, (a5)
    j close
body_128:
    .rept 52
    add s5, s5, s6
    .endr
    la a5, fp4
    vle64.v v0, (a5)
    la a5, fp4
    vle64.v v0, (a5)
    la a5, fp4
    vle64.v v0, (a5)
    la a5, fp4
    vle64.v v0, (a5)
    vid.v v0
    vid.v v1
    vfexp.v v2, ft0
    vfmul.vf v3, v0, ft0
    vfmul.vf v4, v1, ft0
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfexp.v v2, v4
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfadd.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfmul.vv v0, v2, v0
    vfadd.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfdiv.vv v1, v3, v1
    vfmul.vv v1, v4, v2
    vfadd.vv v0, v0, v3
    la a5, fp4
    vse64.v v1, (a5)
    j close
body_256:
    .rept 52
    add s5, s5, s6
    .endr
    la a5, fp5
    vle64.v v0, (a5)
    la a5, fp5
    vle64.v v0, (a5)
    la a5, fp5
    vle64.v v0, (a5)
    la a5, fp5
    vle64.v v0, (a5)
    vid.v v0
    vid.v v1
    vfexp.v v2, ft0
    vfmul.vf v3, v0, ft0
    vfmul.vf v4, v1, ft0
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfexp.v v2, v4
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfmul.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfadd.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfmul.vv v0, v2, v0
    vfadd.vv v1, v3, v1
    vfadd.vv v2, v4, v2
    vfmul.vv v3, v0, v3
    vfadd.vv v4, v1, v4
    vfadd.vv v0, v2, v0
    vfdiv.vv v1, v3, v1
    vfmul.vv v1, v4, v2
    vfadd.vv v0, v0, v3
    la a5, fp5
    vse64.v v1, (a5)
    j close
close:
    sub a3, a3, a4
    bgtz a3, loop
    ret
