# blackscholes: RVV v1.0 kernel emitted by repro.core.codegen -- do not edit.
# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at
# every effective MVL in {8/16/32/64/128/256}; the .chunk loop's bgtz
# counter encodes the exact fractional trip count.
    .text
    .globl blackscholes
    .stream fp0 13824.0
blackscholes:
    vsetvli t0, zero, e64, m1
    li t1, 8
    beq t0, t1, cfg_8
    li t1, 16
    beq t0, t1, cfg_16
    li t1, 32
    beq t0, t1, cfg_32
    li t1, 64
    beq t0, t1, cfg_64
    li t1, 128
    beq t0, t1, cfg_128
    li t1, 256
    beq t0, t1, cfg_256
    j vl_bad
cfg_8:
    li a3, 819200
    li a4, 1
    j cfg_done
cfg_16:
    li a3, 409600
    li a4, 1
    j cfg_done
cfg_32:
    li a3, 204800
    li a4, 1
    j cfg_done
cfg_64:
    li a3, 102400
    li a4, 1
    j cfg_done
cfg_128:
    li a3, 51200
    li a4, 1
    j cfg_done
cfg_256:
    li a3, 25600
    li a4, 1
    j cfg_done
vl_bad:
    call abort
cfg_done:
    .chunk
loop:
    li t1, 8
    beq t0, t1, body_8
    li t1, 16
    beq t0, t1, body_16
    li t1, 32
    beq t0, t1, body_32
    li t1, 64
    beq t0, t1, body_64
    li t1, 128
    beq t0, t1, body_128
    li t1, 256
    beq t0, t1, body_256
    j vl_bad
body_8:
    .rept 244
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    vfdiv.vf v0, ft0, ft1
    vfmul.vf v1, ft0, ft1
    vid.v v2
    vfmul.vf v3, ft0, ft1
    vfmul.vf v4, ft0, ft1
    vfadd.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v0, v1, v7
    vfadd.vv v0, v2, v8
    vfadd.vv v0, v3, v9
    vfmul.vv v0, v4, v10
    la a5, fp0
    vse64.v v3, (a5)
    la a5, fp0
    vse64.v v4, (a5)
    la a5, fp0
    vse64.v v5, (a5)
    la a5, fp0
    vse64.v v6, (a5)
    la a5, fp0
    vse64.v v7, (a5)
    j close
body_16:
    .rept 244
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    vfdiv.vf v0, ft0, ft1
    vfmul.vf v1, ft0, ft1
    vid.v v2
    vfmul.vf v3, ft0, ft1
    vfmul.vf v4, ft0, ft1
    vfadd.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v0, v1, v7
    vfadd.vv v0, v2, v8
    vfadd.vv v0, v3, v9
    vfmul.vv v0, v4, v10
    la a5, fp0
    vse64.v v3, (a5)
    la a5, fp0
    vse64.v v4, (a5)
    la a5, fp0
    vse64.v v5, (a5)
    la a5, fp0
    vse64.v v6, (a5)
    la a5, fp0
    vse64.v v7, (a5)
    j close
body_32:
    .rept 244
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    vfdiv.vf v0, ft0, ft1
    vfmul.vf v1, ft0, ft1
    vid.v v2
    vfmul.vf v3, ft0, ft1
    vfmul.vf v4, ft0, ft1
    vfadd.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v0, v1, v7
    vfadd.vv v0, v2, v8
    vfadd.vv v0, v3, v9
    vfmul.vv v0, v4, v10
    la a5, fp0
    vse64.v v3, (a5)
    la a5, fp0
    vse64.v v4, (a5)
    la a5, fp0
    vse64.v v5, (a5)
    la a5, fp0
    vse64.v v6, (a5)
    la a5, fp0
    vse64.v v7, (a5)
    j close
body_64:
    .rept 244
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    vfdiv.vf v0, ft0, ft1
    vfmul.vf v1, ft0, ft1
    vid.v v2
    vfmul.vf v3, ft0, ft1
    vfmul.vf v4, ft0, ft1
    vfadd.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v0, v1, v7
    vfadd.vv v0, v2, v8
    vfadd.vv v0, v3, v9
    vfmul.vv v0, v4, v10
    la a5, fp0
    vse64.v v3, (a5)
    la a5, fp0
    vse64.v v4, (a5)
    la a5, fp0
    vse64.v v5, (a5)
    la a5, fp0
    vse64.v v6, (a5)
    la a5, fp0
    vse64.v v7, (a5)
    j close
body_128:
    .rept 244
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    vfdiv.vf v0, ft0, ft1
    vfmul.vf v1, ft0, ft1
    vid.v v2
    vfmul.vf v3, ft0, ft1
    vfmul.vf v4, ft0, ft1
    vfadd.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v0, v1, v7
    vfadd.vv v0, v2, v8
    vfadd.vv v0, v3, v9
    vfmul.vv v0, v4, v10
    la a5, fp0
    vse64.v v3, (a5)
    la a5, fp0
    vse64.v v4, (a5)
    la a5, fp0
    vse64.v v5, (a5)
    la a5, fp0
    vse64.v v6, (a5)
    la a5, fp0
    vse64.v v7, (a5)
    j close
body_256:
    .rept 244
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v0, (a5)
    vfdiv.vf v0, ft0, ft1
    vfmul.vf v1, ft0, ft1
    vid.v v2
    vfmul.vf v3, ft0, ft1
    vfmul.vf v4, ft0, ft1
    vfadd.vf v5, v0, ft0
    vfmul.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfexp.v v7, v7
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfexp.v v2, v2
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfdiv.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfexp.v v4, v4
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfexp.v v2, v2
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfadd.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfadd.vv v0, v0, v6
    vfmul.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfmul.vv v5, v5, v0
    vfmul.vv v6, v6, v1
    vfdiv.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfmul.vv v9, v9, v4
    vfadd.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfmul.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfexp.v v7, v7
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfdiv.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfadd.vv v8, v8, v3
    vfdiv.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfdiv.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfmul.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v5, v5, v0
    vfadd.vv v6, v6, v1
    vfmul.vv v7, v7, v2
    vfmul.vv v8, v8, v3
    vfadd.vv v9, v9, v4
    vfmul.vv v10, v10, v5
    vfmul.vv v0, v0, v6
    vfmul.vv v0, v1, v7
    vfadd.vv v0, v2, v8
    vfadd.vv v0, v3, v9
    vfmul.vv v0, v4, v10
    la a5, fp0
    vse64.v v3, (a5)
    la a5, fp0
    vse64.v v4, (a5)
    la a5, fp0
    vse64.v v5, (a5)
    la a5, fp0
    vse64.v v6, (a5)
    la a5, fp0
    vse64.v v7, (a5)
    j close
close:
    sub a3, a3, a4
    bgtz a3, loop
    ret
