# canneal: RVV v1.0 kernel emitted by repro.core.codegen -- do not edit.
# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at
# every effective MVL in {8/16/22}; the .chunk loop's bgtz
# counter encodes the exact fractional trip count.
    .text
    .globl canneal
    .stream fp0 3072.0
canneal:
    vsetvli t0, zero, e64, m1
    vmv.v.i v0, 0
    vmv.v.i v1, 0
    vmv.v.i v2, 0
    vmv.v.i v3, 0
    vmv.v.i v20, 0
    vid.v v31
    vcpop.m s3, v0
    li t1, 8
    beq t0, t1, cfg_8
    li t1, 16
    beq t0, t1, cfg_16
    li t1, 22
    beq t0, t1, cfg_22
    j vl_bad
cfg_8:
    li a3, 1920000
    li a4, 1
    j cfg_done
cfg_16:
    li a3, 1920000
    li a4, 1
    j cfg_done
cfg_22:
    li a3, 1920000
    li a4, 1
    j cfg_done
vl_bad:
    call abort
cfg_done:
    .chunk
loop:
    li t1, 8
    beq t0, t1, body_8
    li t1, 16
    beq t0, t1, body_16
    li t1, 22
    beq t0, t1, body_22
    j vl_bad
body_8:
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    .rept 12
    add s5, s5, s6
    .endr
    la a5, fp0
    vluxei64.v v0, (a5), v31
    la a5, fp0
    vluxei64.v v0, (a5), v31
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfadd.vv v1, v7, v2
    vfadd.vv v1, v8, v3
    vfadd.vv v1, v9, v4
    vfadd.vv v0, v10, v0
    .rept 99
    add s5, s5, s6
    .endr
    la a5, fp0
    vluxei64.v v0, (a5), v31
    la a5, fp0
    vluxei64.v v0, (a5), v31
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfadd.vv v1, v7, v2
    vfadd.vv v1, v8, v3
    vfadd.vv v1, v9, v4
    vfadd.vv v1, v10, v0
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 820
    add s4, s5, s3
    .endr
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    .rept 12
    add s5, s5, s6
    .endr
    la a5, fp0
    vluxei64.v v0, (a5), v31
    la a5, fp0
    vluxei64.v v0, (a5), v31
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfadd.vv v1, v7, v2
    vfadd.vv v1, v8, v3
    vfadd.vv v1, v9, v4
    vfadd.vv v0, v10, v0
    .rept 99
    add s5, s5, s6
    .endr
    la a5, fp0
    vluxei64.v v0, (a5), v31
    la a5, fp0
    vluxei64.v v0, (a5), v31
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfadd.vv v1, v7, v2
    vfadd.vv v1, v8, v3
    vfadd.vv v1, v9, v4
    vfadd.vv v1, v10, v0
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 820
    add s4, s5, s3
    .endr
    j close
body_16:
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    .rept 12
    add s5, s5, s6
    .endr
    li t2, 12
    vsetvli zero, t2, e64, m1
    la a5, fp0
    vluxei64.v v0, (a5), v31
    la a5, fp0
    vluxei64.v v0, (a5), v31
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfadd.vv v1, v7, v2
    vfadd.vv v1, v8, v3
    vfadd.vv v1, v9, v4
    vfadd.vv v1, v10, v0
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 820
    add s4, s5, s3
    .endr
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    .rept 12
    add s5, s5, s6
    .endr
    la a5, fp0
    vluxei64.v v0, (a5), v31
    la a5, fp0
    vluxei64.v v0, (a5), v31
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfadd.vv v1, v7, v2
    vfadd.vv v1, v8, v3
    vfadd.vv v1, v9, v4
    vfadd.vv v1, v10, v0
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 820
    add s4, s5, s3
    .endr
    j close
body_22:
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    .rept 12
    add s5, s5, s6
    .endr
    li t2, 12
    vsetvli zero, t2, e64, m1
    la a5, fp0
    vluxei64.v v0, (a5), v31
    la a5, fp0
    vluxei64.v v0, (a5), v31
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfadd.vv v1, v7, v2
    vfadd.vv v1, v8, v3
    vfadd.vv v1, v9, v4
    vfadd.vv v1, v10, v0
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 820
    add s4, s5, s3
    .endr
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    vmv1r.v v8, v0
    vmv1r.v v9, v1
    vmv1r.v v10, v2
    vmv1r.v v11, v3
    .rept 12
    add s5, s5, s6
    .endr
    la a5, fp0
    vluxei64.v v0, (a5), v31
    la a5, fp0
    vluxei64.v v0, (a5), v31
    vid.v v0
    vid.v v1
    vid.v v2
    vid.v v3
    vid.v v4
    vfadd.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfadd.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfadd.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfadd.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfadd.vv v1, v7, v2
    vfadd.vv v1, v8, v3
    vfadd.vv v1, v9, v4
    vfadd.vv v1, v10, v0
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 820
    add s4, s5, s3
    .endr
    j close
close:
    sub a3, a3, a4
    bgtz a3, loop
    ret
