# jacobi-2d: RVV v1.0 kernel emitted by repro.core.codegen -- do not edit.
# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at
# every effective MVL in {8/16/32/64/128/256}; the .chunk loop's bgtz
# counter encodes the exact fractional trip count.
    .text
    .globl jacobi_2d
    .stream fp0 408.0
jacobi_2d:
    vsetvli t0, zero, e64, m1
    li t1, 8
    beq t0, t1, cfg_8
    li t1, 16
    beq t0, t1, cfg_16
    li t1, 32
    beq t0, t1, cfg_32
    li t1, 64
    beq t0, t1, cfg_64
    li t1, 128
    beq t0, t1, cfg_128
    li t1, 256
    beq t0, t1, cfg_256
    j vl_bad
cfg_8:
    li a3, 13056000
    li a4, 1
    j cfg_done
cfg_16:
    li a3, 6528000
    li a4, 1
    j cfg_done
cfg_32:
    li a3, 3264000
    li a4, 1
    j cfg_done
cfg_64:
    li a3, 1632000
    li a4, 1
    j cfg_done
cfg_128:
    li a3, 816000
    li a4, 1
    j cfg_done
cfg_256:
    li a3, 408000
    li a4, 1
    j cfg_done
vl_bad:
    call abort
cfg_done:
    .chunk
loop:
    li t1, 8
    beq t0, t1, body_8
    li t1, 16
    beq t0, t1, body_16
    li t1, 32
    beq t0, t1, body_32
    li t1, 64
    beq t0, t1, body_64
    li t1, 128
    beq t0, t1, body_128
    li t1, 256
    beq t0, t1, body_256
    j vl_bad
body_8:
    .rept 87
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    vslide1down.vx v1, v0, t5
    vslide1down.vx v0, v0, t5
    vfmul.vf v0, ft0, ft1
    vid.v v1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfmul.vv v2, v7, v2
    vfmul.vv v3, v8, v3
    vslide1down.vx v0, v0, t5
    vslide1down.vx v1, v1, t5
    vslide1down.vx v1, v2, t5
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_16:
    .rept 87
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    vslide1down.vx v1, v0, t5
    vslide1down.vx v0, v0, t5
    vfmul.vf v0, ft0, ft1
    vid.v v1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfmul.vv v2, v7, v2
    vfmul.vv v3, v8, v3
    vslide1down.vx v0, v0, t5
    vslide1down.vx v1, v1, t5
    vslide1down.vx v1, v2, t5
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_32:
    .rept 87
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    vslide1down.vx v1, v0, t5
    vslide1down.vx v0, v0, t5
    vfmul.vf v0, ft0, ft1
    vid.v v1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfmul.vv v2, v7, v2
    vfmul.vv v3, v8, v3
    vslide1down.vx v0, v0, t5
    vslide1down.vx v1, v1, t5
    vslide1down.vx v1, v2, t5
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_64:
    .rept 87
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    vslide1down.vx v1, v0, t5
    vslide1down.vx v0, v0, t5
    vfmul.vf v0, ft0, ft1
    vid.v v1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfmul.vv v2, v7, v2
    vfmul.vv v3, v8, v3
    vslide1down.vx v0, v0, t5
    vslide1down.vx v1, v1, t5
    vslide1down.vx v1, v2, t5
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_128:
    .rept 87
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    vslide1down.vx v1, v0, t5
    vslide1down.vx v0, v0, t5
    vfmul.vf v0, ft0, ft1
    vid.v v1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfmul.vv v2, v7, v2
    vfmul.vv v3, v8, v3
    vslide1down.vx v0, v0, t5
    vslide1down.vx v1, v1, t5
    vslide1down.vx v1, v2, t5
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_256:
    .rept 87
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    la a5, fp0
    vle64.v v1, (a5)
    vslide1down.vx v1, v0, t5
    vslide1down.vx v0, v0, t5
    vfmul.vf v0, ft0, ft1
    vid.v v1
    vfmul.vf v2, ft0, ft1
    vid.v v3
    vid.v v4
    vfmul.vf v5, v0, ft0
    vfadd.vf v6, v1, ft0
    vfmul.vf v7, v2, ft0
    vfadd.vf v8, v3, ft0
    vfadd.vf v9, v4, ft0
    vfadd.vf v10, v5, ft0
    vfmul.vv v0, v0, v6
    vfadd.vv v1, v1, v7
    vfadd.vv v2, v2, v8
    vfmul.vv v3, v3, v9
    vfadd.vv v4, v4, v10
    vfadd.vv v0, v5, v0
    vfadd.vv v1, v6, v1
    vfmul.vv v2, v7, v2
    vfmul.vv v3, v8, v3
    vslide1down.vx v0, v0, t5
    vslide1down.vx v1, v1, t5
    vslide1down.vx v1, v2, t5
    la a5, fp0
    vse64.v v0, (a5)
    j close
close:
    sub a3, a3, a4
    bgtz a3, loop
    ret
