# streamcluster: RVV v1.0 kernel emitted by repro.core.codegen -- do not edit.
# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at
# every effective MVL in {8/16/32/64/128}; the .chunk loop's bgtz
# counter encodes the exact fractional trip count.
    .text
    .globl streamcluster
    .stream fp0 768.0
streamcluster:
    vsetvli t0, zero, e64, m1
    vmv.v.i v20, 0
    vmv.v.i v0, 0
    vcpop.m s3, v0
    li t1, 8
    beq t0, t1, cfg_8
    li t1, 16
    beq t0, t1, cfg_16
    li t1, 32
    beq t0, t1, cfg_32
    li t1, 64
    beq t0, t1, cfg_64
    li t1, 128
    beq t0, t1, cfg_128
    j vl_bad
cfg_8:
    li a3, 59533158
    li a4, 1
    j cfg_done
cfg_16:
    li a3, 59533158
    li a4, 1
    j cfg_done
cfg_32:
    li a3, 59533158
    li a4, 1
    j cfg_done
cfg_64:
    li a3, 59533158
    li a4, 1
    j cfg_done
cfg_128:
    li a3, 59533158
    li a4, 1
    j cfg_done
vl_bad:
    call abort
cfg_done:
    .chunk
loop:
    li t1, 8
    beq t0, t1, body_8
    li t1, 16
    beq t0, t1, body_16
    li t1, 32
    beq t0, t1, body_32
    li t1, 64
    beq t0, t1, body_64
    li t1, 128
    beq t0, t1, body_128
    j vl_bad
body_8:
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vv v0, v0, v0
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 30
    add s4, s5, s3
    .endr
    j close
body_16:
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vv v0, v0, v0
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 30
    add s4, s5, s3
    .endr
    j close
body_32:
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vv v0, v0, v0
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 30
    add s4, s5, s3
    .endr
    j close
body_64:
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vv v0, v0, v0
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vv v0, v0, v1
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 30
    add s4, s5, s3
    .endr
    j close
body_128:
    .rept 2
    add s5, s5, s6
    .endr
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vv v0, v0, v0
    vfredusum.vs v0, v0, v0
    vcpop.m t6, v20
    .rept 30
    add s4, s5, s3
    .endr
    j close
close:
    sub a3, a3, a4
    bgtz a3, loop
    ret
