# decode_attention: RVV v1.0 kernel emitted by repro.core.codegen -- do not edit.
# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at
# every effective MVL in {8/16/32/64/128/256}; the .chunk loop's bgtz
# counter encodes the exact fractional trip count.
    .text
    .globl decode_attention
    .stream fp0 8192.0
decode_attention:
    vsetvli t0, zero, e64, m1
    vmv.v.i v0, 0
    vcpop.m s3, v0
    li t1, 8
    beq t0, t1, cfg_8
    li t1, 16
    beq t0, t1, cfg_16
    li t1, 32
    beq t0, t1, cfg_32
    li t1, 64
    beq t0, t1, cfg_64
    li t1, 128
    beq t0, t1, cfg_128
    li t1, 256
    beq t0, t1, cfg_256
    j vl_bad
cfg_8:
    li a3, 131072
    li a4, 1
    j cfg_done
cfg_16:
    li a3, 65536
    li a4, 1
    j cfg_done
cfg_32:
    li a3, 32768
    li a4, 1
    j cfg_done
cfg_64:
    li a3, 16384
    li a4, 1
    j cfg_done
cfg_128:
    li a3, 8192
    li a4, 1
    j cfg_done
cfg_256:
    li a3, 4096
    li a4, 1
    j cfg_done
vl_bad:
    call abort
cfg_done:
    .chunk
loop:
    li t1, 8
    beq t0, t1, body_8
    li t1, 16
    beq t0, t1, body_16
    li t1, 32
    beq t0, t1, body_32
    li t1, 64
    beq t0, t1, body_64
    li t1, 128
    beq t0, t1, body_128
    li t1, 256
    beq t0, t1, body_256
    j vl_bad
body_8:
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vf v0, v0, ft0
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    vid.v v1
    vfadd.vv v0, v1, v0
    vfredusum.vs v1, v0, v0
    vfadd.vv v0, v0, v1
    vfexp.v v0, v0
    vfredusum.vs v1, v0, v0
    .rept 6
    add s4, s5, s3
    .endr
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v0, v0, v1
    vfredusum.vs v1, v0, v0
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_16:
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vf v0, v0, ft0
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    vid.v v1
    vfadd.vv v0, v1, v0
    vfredusum.vs v1, v0, v0
    vfadd.vv v0, v0, v1
    vfexp.v v0, v0
    vfredusum.vs v1, v0, v0
    .rept 6
    add s4, s5, s3
    .endr
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v0, v0, v1
    vfredusum.vs v1, v0, v0
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_32:
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vf v0, v0, ft0
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    vid.v v1
    vfadd.vv v0, v1, v0
    vfredusum.vs v1, v0, v0
    vfadd.vv v0, v0, v1
    vfexp.v v0, v0
    vfredusum.vs v1, v0, v0
    .rept 6
    add s4, s5, s3
    .endr
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v0, v0, v1
    vfredusum.vs v1, v0, v0
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_64:
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vf v0, v0, ft0
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    vid.v v1
    vfadd.vv v0, v1, v0
    vfredusum.vs v1, v0, v0
    vfadd.vv v0, v0, v1
    vfexp.v v0, v0
    vfredusum.vs v1, v0, v0
    .rept 6
    add s4, s5, s3
    .endr
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v0, v0, v1
    vfredusum.vs v1, v0, v0
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_128:
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vf v0, v0, ft0
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    vid.v v1
    vfadd.vv v0, v1, v0
    vfredusum.vs v1, v0, v0
    vfadd.vv v0, v0, v1
    vfexp.v v0, v0
    vfredusum.vs v1, v0, v0
    .rept 6
    add s4, s5, s3
    .endr
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v0, v0, v1
    vfredusum.vs v1, v0, v0
    la a5, fp0
    vse64.v v0, (a5)
    j close
body_256:
    la a5, fp0
    vle64.v v0, (a5)
    vfmul.vf v0, v0, ft0
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    la a5, fp0
    vle64.v v1, (a5)
    vfmul.vf v1, v1, ft0
    vfadd.vv v0, v0, v1
    vid.v v1
    vfadd.vv v0, v1, v0
    vfredusum.vs v1, v0, v0
    vfadd.vv v0, v0, v1
    vfexp.v v0, v0
    vfredusum.vs v1, v0, v0
    .rept 6
    add s4, s5, s3
    .endr
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v1, v0, v1
    vfredusum.vs v1, v1, v1
    la a5, fp0
    vlse64.v v1, (a5), t3
    vfmul.vv v0, v0, v1
    vfredusum.vs v1, v0, v0
    la a5, fp0
    vse64.v v0, (a5)
    j close
close:
    sub a3, a3, a4
    bgtz a3, loop
    ret
