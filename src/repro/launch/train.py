"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --shape train_4k [--multi-pod] [--steps N] [--smoke]

On real hardware this runs against the production mesh; with --smoke it runs
the reduced config on the local devices (CI / laptop path).  Fault tolerance
(checkpoint/restart/retry) comes from repro.train.loop.
"""
import argparse

import jax

from repro.configs import SHAPES, get_config
from repro.configs.base import InputShape
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build
from repro.train.loop import LoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        shape = InputShape("smoke", 32, 8, "train")
        mesh = None
    else:
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    model = build(cfg)
    state = train(model, shape, mesh,
                  loop_cfg=LoopConfig(total_steps=args.steps,
                                      ckpt_every=max(args.steps // 4, 1),
                                      ckpt_dir=args.ckpt))
    print(f"done: {state.step} steps, final loss {state.losses[-1]:.4f}, "
          f"restarts {state.restarts}")


if __name__ == "__main__":
    main()
