"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never touches
jax device state.  Single pod: 256 chips as (16, 16) ("data", "model").
Multi-pod: 2 pods x 256 chips as (2, 16, 16) ("pod", "data", "model").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(*, data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
