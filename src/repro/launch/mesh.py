"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never touches
jax device state.  Single pod: 256 chips as (16, 16) ("data", "model").
Multi-pod: 2 pods x 256 chips as (2, 16, 16) ("pod", "data", "model").
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer JAX (>= 0.6); older releases
    default every axis to auto sharding, which is exactly what we request,
    so the fallback is simply to omit the argument."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_compat_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with auto axis types on any installed JAX version."""
    axes = tuple(axes)
    return jax.make_mesh(tuple(shape), axes, **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return make_compat_mesh((data, model), ("data", "model"))
