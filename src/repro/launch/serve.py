"""Production serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke

--smoke serves the reduced config on local devices with synthetic requests;
on hardware, drop --smoke to shard over the production mesh (prefill/decode
step builders in repro.train.trainstep carry the shardings).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": jax.random.normal(
            jax.random.key(1), (args.batch_size, cfg.num_frames, cfg.d_model))}
    if cfg.family == "vlm":
        extra = {"patches": jax.random.normal(
            jax.random.key(1), (args.batch_size, cfg.num_patches, cfg.d_model))}
    engine = ServeEngine(model, params, args.batch_size, max_seq=64, extra=extra)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, rng.integers(3, 10)).astype(np.int32),
            max_new_tokens=args.max_new_tokens))
    t0 = time.time()
    done = engine.run()
    tok = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {tok} tokens, {tok/(time.time()-t0):.1f} tok/s")


if __name__ == "__main__":
    main()
