import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this lowers the train/prefill/decode step with
ShapeDtypeStruct stand-ins (no allocation), compiles it against the production
mesh, prints ``memory_analysis()`` (proves it fits) and ``cost_analysis()``
(FLOPs/bytes for the roofline), parses collective bytes out of the HLO, and
appends a JSON record consumed by ``benchmarks/roofline_report.py`` and
EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, iter_cells
from repro.core import hlo_analysis, roofline
from repro.launch.mesh import make_production_mesh
from repro.models import api as mapi
from repro.train import trainstep

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results")


def _builder(model, shape, mesh, micro=None):
    if shape.kind == "train":
        fn, in_sh, out_sh, donate = trainstep.build_train_step(
            model, shape, mesh, microbatches=micro)
        args = (model.param_structs(), trainstep.opt_structs(model.param_structs()),
                mapi.input_specs(model.cfg, shape))
    elif shape.kind == "prefill":
        fn, in_sh, out_sh, donate = trainstep.build_prefill_step(model, shape, mesh)
        args = (model.param_structs(), mapi.input_specs(model.cfg, shape))
    else:
        fn, in_sh, out_sh, donate = trainstep.build_decode_step(model, shape, mesh)
        cache, tokens, pos = trainstep.decode_inputs(model, shape)
        args = (model.param_structs(), cache, tokens, pos)
    return fn, in_sh, out_sh, donate, args


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             micro=None, overrides=None, tag="") -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    model = mapi.build(cfg)
    fn, in_sh, out_sh, donate, args = _builder(model, shape, mesh, micro=micro)

    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    # NOTE: xla's cost_analysis() counts while (lax.scan) bodies once; our
    # analyzer applies loop trip counts (see core/hlo_analysis.py docstring).
    hlo = hlo_analysis.analyze(txt)

    mf = roofline.model_flops(cfg, shape)
    rl = roofline.Roofline(
        flops=hlo["flops"],
        hbm_bytes=hlo["hbm_bytes"],
        ici_bytes=hlo["ici_bytes"],
        model_flops=mf,
        chips=chips,
    )
    hbm_used = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": arch, "shape": shape_name, "tag": tag,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "hbm_used_bytes": hbm_used,
            "fits_16GB": bool(hbm_used < 16e9),
            "flops": hlo["flops"],
            "hbm_bytes_accessed": hlo["hbm_bytes"],
            "ici_bytes": hlo["ici_bytes"],
            "ici_by_op": hlo["by_op"],
            "static_collectives": hlo["static_collective_count"],
            "xla_cost_flops_unscaled": float(cost.get("flops", 0.0)),
            "xla_cost_bytes_unscaled": float(cost.get("bytes accessed", 0.0)),
        },
        "model_flops": mf,
        "roofline": rl.row(),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']}] "
              f"compile={t_compile:.1f}s "
              f"hbm={hbm_used/2**30:.2f}GiB fits={rec['per_device']['fits_16GB']} "
              f"flops={rec['per_device']['flops']:.3e} "
              f"ici={hlo['ici_bytes']:.3e}B bound={rl.bound} "
              f"frac={rl.mfu_bound:.3f}")
        print("  memory_analysis:", mem)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--cache-dtype", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.join(RESULTS, "dryrun.jsonl"))
    args = ap.parse_args()

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for cfg, shape, ok, why in iter_cells():
            if ok:
                cells.append((cfg.name, shape.name))
            else:
                print(f"SKIP {cfg.name} x {shape.name}: {why}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    with open(args.out, "a") as f:
        for arch, shape_name in cells:
            for multi in meshes:
                try:
                    overrides = {}
                    if args.ssm_chunk:
                        overrides["ssm_chunk"] = args.ssm_chunk
                    if args.cache_dtype:
                        overrides["cache_dtype"] = args.cache_dtype
                    if args.attn_chunk:
                        from repro.models import layers as _L
                        _L.ATTN_CHUNK = args.attn_chunk
                    rec = run_cell(arch, shape_name, multi, micro=args.micro,
                                   overrides=overrides, tag=args.tag)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                except Exception:
                    failures += 1
                    print(f"FAILED {arch} x {shape_name} multi={multi}")
                    traceback.print_exc()
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
