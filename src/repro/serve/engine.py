"""Batched serving engine: continuous-batching prefill/decode driver.

Requests queue up; the engine prefills prompts into KV-cache slots, then
decodes the batch in lock-step, retiring finished sequences and backfilling
from the queue (continuous batching at wave granularity).  All device work
goes through the jitted prefill/decode steps, so the same engine drives a
smoke model on CPU and the production mesh on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


def serve_batch(model, params, prompts, max_new_tokens: int, max_seq: int,
                extra: dict | None = None) -> list[list[int]]:
    """Greedy batched generation."""
    B = len(prompts)
    S = max(len(p) for p in prompts)
    toks = np.zeros((B, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, S - len(p):] = p                  # left-pad
    batch = {"tokens": jnp.asarray(toks)}
    if extra:
        batch.update(extra)
    logits, cache = model.prefill(params, batch, max_seq)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    outs = [[] for _ in prompts]
    pos = S if model.cfg.family != "vlm" else S + model.cfg.num_patches
    for t in range(max_new_tokens):
        for i in range(B):
            outs[i].append(int(tok[i, 0]))
        if t == max_new_tokens - 1:
            break
        logits, cache = decode(params, cache, tok, jnp.int32(pos + t))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return outs


class ServeEngine:
    """Wave-granularity continuous batching over `serve_batch`."""

    def __init__(self, model, params, batch_size: int, max_seq: int,
                 extra: dict | None = None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.extra = extra
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self) -> list[Request]:
        while self.queue:
            wave = self.queue[:self.B]
            self.queue = self.queue[self.B:]
            prompts = [r.prompt for r in wave]
            while len(prompts) < self.B:          # pad the wave
                prompts.append(wave[0].prompt)
            steps = max(r.max_new_tokens for r in wave)
            outs = serve_batch(self.model, self.params, prompts, steps,
                               self.max_seq, self.extra)
            for r, o in zip(wave, outs):
                r.out_tokens = o[:r.max_new_tokens]
                r.done = True
                self.finished.append(r)
        return self.finished
