"""Batched serving engine: continuous-batching prefill/decode driver.

Requests queue up; the engine prefills prompts into KV-cache slots, then
decodes the batch in lock-step, retiring each sequence as soon as it reaches
its own token budget and backfilling its slot from the queue (continuous
batching at retire granularity).  All device work goes through the jitted
prefill/decode steps, so the same engine drives a smoke model on CPU and the
production mesh on TPU.

The same retire-and-backfill wave structure drives the repo's
*simulation-as-a-service* layer (``repro.serve.sim_service``), where the
"model" is the vector-engine timing scan and a "request" is an
(app, config) simulation cell.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


def serve_batch(model, params, prompts, max_new_tokens: int, max_seq: int,
                extra: dict | None = None) -> list[list[int]]:
    """Greedy batched generation."""
    B = len(prompts)
    S = max(len(p) for p in prompts)
    toks = np.zeros((B, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, S - len(p):] = p                  # left-pad
    batch = {"tokens": jnp.asarray(toks)}
    if extra:
        batch.update(extra)
    logits, cache = model.prefill(params, batch, max_seq)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    outs = [[] for _ in prompts]
    pos = S if model.cfg.family != "vlm" else S + model.cfg.num_patches
    for t in range(max_new_tokens):
        for i in range(B):
            outs[i].append(int(tok[i, 0]))
        if t == max_new_tokens - 1:
            break
        logits, cache = decode(params, cache, tok, jnp.int32(pos + t))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return outs


class ServeEngine:
    """Continuous batching over the prefill/decode model API.

    ``run()`` keeps up to ``batch_size`` active slots.  A sequence retires
    the moment it reaches its *own* ``max_new_tokens`` and its slot is
    backfilled from the FIFO queue — no slot ever decodes past its budget
    (the pre-fix wave barrier decoded ``max(max_new_tokens)`` lock-step for
    every wave member and padded short waves with duplicate prompts treated
    as work).

    Because ``decode_step`` advances all slots at one shared position, a
    backfill round re-prefills the active set (each prompt plus the tokens
    it has generated so far): prompt processing is a single batched pass,
    so a round costs one prefill + ``min(remaining budgets)`` decode steps.
    Dead slots (when fewer than ``batch_size`` sequences are active) are
    shape padding only — their outputs are never read.  ``decode_steps`` /
    ``prefill_rounds`` expose the work actually done.
    """

    def __init__(self, model, params, batch_size: int, max_seq: int,
                 extra: dict | None = None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.extra = extra
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.decode_steps = 0
        self.prefill_rounds = 0
        self._decode = jax.jit(model.decode_step)

    def submit(self, req: Request):
        self.queue.append(req)

    def _retire(self, active: list[Request]) -> None:
        for r in [r for r in active
                  if len(r.out_tokens) >= r.max_new_tokens]:
            r.done = True
            self.finished.append(r)
            active.remove(r)

    def _round(self, active: list[Request]) -> None:
        """One continuous-batching round: prefill prompt+generated for every
        active slot, then decode until the first slot exhausts its budget."""
        prompts = [np.concatenate([np.asarray(r.prompt, np.int32),
                                   np.asarray(r.out_tokens, np.int32)])
                   for r in active]
        steps = min(r.max_new_tokens - len(r.out_tokens) for r in active)
        padded = prompts + [prompts[0]] * (self.B - len(prompts))
        S = max(len(p) for p in padded)
        toks = np.zeros((self.B, S), np.int32)
        for i, p in enumerate(padded):
            toks[i, S - len(p):] = p                  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.extra:
            batch.update(self.extra)
        logits, cache = self.model.prefill(self.params, batch, self.max_seq)
        self.prefill_rounds += 1
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        pos = S if self.model.cfg.family != "vlm" else \
            S + self.model.cfg.num_patches
        for t in range(steps):
            for i, r in enumerate(active):
                r.out_tokens.append(int(tok[i, 0]))
            if t == steps - 1:
                break
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(pos + t))
            self.decode_steps += 1
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)

    def run(self) -> list[Request]:
        active: list[Request] = []
        while self.queue or active:
            while self.queue and len(active) < self.B:   # backfill FIFO
                active.append(self.queue.pop(0))
            self._retire(active)          # handles max_new_tokens == 0 too
            if not active:
                continue
            self._round(active)
            self._retire(active)
        return self.finished
