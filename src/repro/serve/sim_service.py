"""Simulation-as-a-service: async batched serving of simulation requests.

ROADMAP item 3: treat simulation requests the way a production inference
server treats user queries.  A :class:`SimService` accepts a stream of
``(app | "app:asm" | kernel-trace, config)`` requests and answers them
through three tiers:

* **hit path** — the request's cell key (``dse.cell_key``: the same
  ``model|trace|config|warmup/measure`` fingerprint the DSE sweeps use) is
  already in the :class:`~repro.core.dse.ResultCache`; the answer is
  returned immediately, no device dispatch.
* **coalesced** — an identical cell is already queued cold; the request
  rides that dispatch (one simulation, N answers).  Configs that alias to
  the same clamped body + timing parameters (e.g. ``mvl`` above an app's
  ``max_vl``) coalesce for free because they share a key.
* **batched** — cold requests queue until ``max_batch`` of them are waiting
  or the oldest has waited ``max_wait_s``; the batch goes to
  ``engine.steady_state_time_batch`` — the same ``(batch bucket, CHUNK)``
  jit-keyed chunked scan (sharded over devices when >1) every sweep uses —
  so a service answer is bitwise the sweep answer.  :meth:`SimService.prewarm`
  compiles one executable per power-of-two batch bucket up front, after
  which steady-state serving never recompiles.

Robustness contract: the queue is bounded (``max_queue`` waiting requests);
on overflow the service degrades gracefully — ``overflow="serialize"``
dispatches the backlog inline (latency, not loss), ``overflow="shed"``
rejects the request with a ``source="shed"`` answer.  Every dispatch is
synchronous, so no path can deadlock.  Cache writes go through the
crash-safe locked single-write ``ResultCache.flush`` after every batch.

Observability: every answer is a :class:`SimResult` carrying arrival /
completion stamps and latency; :func:`run_workload` drives a (seeded,
deterministic) Poisson arrival stream through the service — in realtime
mode sleeping out the true inter-arrival gaps — and reduces the records to
p50/p99 latency, sustained throughput, hit/coalesce/shed counts and
recompile deltas (:class:`ServeReport`).

``python -m repro.serve.sim_service --smoke`` is the CI gate: a short
Poisson run must finish with zero post-prewarm recompiles, and a repeat
pass against the persisted cache must answer >= 99 % of requests from the
cache with bitwise-identical times.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import dse
from repro.core import engine as eng
from repro.core import isa, suite, telemetry, tracegen


# --------------------------------------------------------------------------
# request / result records
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class SimRequest:
    """One simulation request: an app name (``"canneal"``, ``"canneal:asm"``)
    or a raw ``isa.Trace`` loop body (a *kernel* request), plus the engine
    config to time it on."""
    uid: int
    app: object                 # str | isa.Trace
    cfg: eng.VectorEngineConfig
    t_arrival: float


@dataclass(frozen=True)
class SimResult:
    """One answered request, with its latency record.

    ``source`` is the serving tier: ``"cache"`` (hit, no dispatch),
    ``"batched"`` (first rider of a cold dispatch), ``"coalesced"`` (rode an
    already-queued identical cell) or ``"shed"`` (rejected on overflow;
    ``steady_ns`` is NaN).  For kernel (raw-trace) requests the whole-app
    quantities ``runtime_ns``/``speedup`` are NaN — there is no chunk count
    or scalar baseline to derive them from.
    """
    uid: int
    app: str
    label: str
    steady_ns: float
    runtime_ns: float
    speedup: float
    source: str
    t_arrival: float
    t_done: float
    latency_s: float
    batch_id: int | None = None


@dataclass
class _PendingCell:
    """One cold cell awaiting dispatch, with every request riding it."""
    key: str
    body: isa.Trace
    cfg: eng.VectorEngineConfig
    reqs: list = field(default_factory=list)
    t_enqueue: float = 0.0


# --------------------------------------------------------------------------
# the service
# --------------------------------------------------------------------------

class SimService:
    """Async batched request serving over the vector-engine timing model.

    Single-object, thread-safe (an RLock serializes submit/flush), and
    synchronous at the dispatch boundary: ``submit`` returns immediately
    with a :class:`SimResult` for hits/sheds and ``None`` for queued cold
    requests, whose results arrive in :attr:`completed` (and by uid via
    :meth:`result_for`) when their batch dispatches — on :meth:`flush`,
    :meth:`drain`, or automatically when the batch fills.
    """

    def __init__(self, cache: dse.ResultCache | None = None,
                 max_batch: int = 32, max_wait_s: float = 0.05,
                 max_queue: int = 128, overflow: str = "serialize",
                 warmup: int = 8, measure: int = 24,
                 clock=time.perf_counter, snapshot_every: int = 0):
        if overflow not in ("serialize", "shed"):
            raise ValueError(f"overflow={overflow!r}: 'serialize' or 'shed'")
        if max_batch < 1 or max_queue < 1:
            raise ValueError("max_batch and max_queue must be >= 1")
        self.cache = cache if cache is not None else dse.ResultCache()
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_queue = max_queue
        self.overflow = overflow
        self.warmup = warmup
        self.measure = measure
        self.clock = clock
        self.completed: list[SimResult] = []
        self.shed: list[SimResult] = []
        self._results: dict[int, SimResult] = {}
        self._pending: dict[str, _PendingCell] = {}   # insertion-ordered
        self._waiting = 0                             # riders across cells
        self._uid = itertools.count()
        self._lock = threading.RLock()
        self._model_fp = eng.model_fingerprint()
        # observability counters
        self.n_requests = 0
        self.n_hits = 0
        self.n_coalesced = 0
        self.n_dispatched = 0     # unique cells simulated
        self.n_shed = 0
        self.n_serialized = 0     # overflow-forced inline flushes
        self.n_batches = 0
        self.recompiles = 0       # jit-cache growth across dispatches
        # bounded log-spaced latency histogram: percentiles (incl. p99.9)
        # without retaining per-request records; plus optional periodic
        # stats snapshots (telemetry.SCHEMA rows) every N completions
        self.lat_hist = telemetry.LatencyHistogram()
        self.snapshot_every = snapshot_every
        self.snapshots: list[dict] = []

    # ---- keying ----------------------------------------------------------

    def _cell(self, app, cfg):
        """(display name, body, cache key) for a request payload."""
        if isinstance(app, isa.Trace):
            fp = isa.trace_fingerprint(app)
            key = (f"{self._model_fp}|{fp}|{dse.config_fp(cfg)}"
                   f"|w{self.warmup}m{self.measure}")
            return f"kernel:{fp[:8]}", app, key
        body, key = dse.cell_key(app, cfg, self.warmup, self.measure,
                                 model_fp=self._model_fp)
        return app, body, key

    # ---- submission ------------------------------------------------------

    def submit(self, app, cfg: eng.VectorEngineConfig,
               now: float | None = None):
        """Submit one request.  Returns the :class:`SimResult` when it can be
        answered synchronously (cache hit, or shed on overflow), else
        ``None`` — the result lands in :attr:`completed` at dispatch."""
        with self._lock:
            now = self.clock() if now is None else now
            req = SimRequest(next(self._uid), app, cfg, now)
            self.n_requests += 1
            name, body, key = self._cell(app, cfg)
            per_chunk = self.cache.get(key)
            if per_chunk is not None:
                return self._complete(req, name, body, per_chunk, "cache",
                                      self.clock(), None)
            cell = self._pending.get(key)
            if cell is not None:                      # coalesce onto it
                cell.reqs.append((req, name))
                self._waiting += 1
                self.n_coalesced += 1
                return None
            if self._waiting >= self.max_queue:       # bounded queue
                if self.overflow == "shed":
                    self.n_shed += 1
                    res = SimResult(
                        uid=req.uid, app=name, label=cfg.label(),
                        steady_ns=float("nan"), runtime_ns=float("nan"),
                        speedup=float("nan"), source="shed",
                        t_arrival=req.t_arrival, t_done=now, latency_s=0.0)
                    self.shed.append(res)
                    self._results[req.uid] = res
                    return res
                self.n_serialized += 1                # serialize: drain now
                self.flush()
            self._pending[key] = _PendingCell(key, body, cfg,
                                              reqs=[(req, name)],
                                              t_enqueue=now)
            self._waiting += 1
            if len(self._pending) >= self.max_batch:
                self.flush()
            return None

    # ---- batching / dispatch --------------------------------------------

    def pending_requests(self) -> int:
        return self._waiting

    def batch_ready(self) -> bool:
        return len(self._pending) >= self.max_batch

    def next_deadline(self) -> float | None:
        """Absolute clock time at which the oldest pending cell times out
        (the per-batch timeout), or None when nothing is queued."""
        with self._lock:
            if not self._pending:
                return None
            head = next(iter(self._pending.values()))
            return head.t_enqueue + self.max_wait_s

    def flush(self, now: float | None = None) -> int:
        """Dispatch every pending cell in ``max_batch``-sized batches through
        the engine's jit-keyed chunked scan.  Returns cells dispatched."""
        with self._lock:
            done = 0
            while self._pending:
                keys = list(itertools.islice(iter(self._pending),
                                             self.max_batch))
                batch = [self._pending.pop(k) for k in keys]
                jc0 = eng.jit_cache_size()
                times = eng.steady_state_time_batch(
                    [c.body for c in batch], [c.cfg for c in batch],
                    warmup=self.warmup, measure=self.measure)
                jc1 = eng.jit_cache_size()
                if jc0 >= 0 and jc1 >= 0:
                    self.recompiles += jc1 - jc0
                self.n_batches += 1
                batch_id = self.n_batches
                t_done = self.clock()
                for cell, t in zip(batch, times):
                    self.cache.put(cell.key, float(t))
                    self.n_dispatched += 1
                    done += 1
                    for i, (req, name) in enumerate(cell.reqs):
                        self._complete(req, name, cell.body, float(t),
                                       "batched" if i == 0 else "coalesced",
                                       t_done, batch_id)
                        self._waiting -= 1
                self.cache.flush()        # crash-safe persist per batch
            return done

    def drain(self) -> None:
        """Dispatch until nothing is pending (never blocks on anything but
        the dispatches themselves — cannot deadlock)."""
        self.flush()

    def prewarm(self) -> int:
        """Compile the batched scan at every power-of-two batch bucket up to
        ``max_batch`` (the only jit key of the batched path), so steady-state
        serving never recompiles.  Returns the number of buckets warmed."""
        with self._lock:
            cfg = eng.VectorEngineConfig(mvl=8, lanes=1)
            body = tracegen.body_for("blackscholes",
                                     suite.effective_mvl("blackscholes", cfg),
                                     cfg)
            buckets, b = [], 8
            while b <= eng.batch_bucket(self.max_batch):
                buckets.append(b)
                b *= 2
            for b in buckets:
                eng.steady_state_time_batch([body] * b, [cfg] * b,
                                            warmup=self.warmup,
                                            measure=self.measure)
            return len(buckets)

    # ---- completion ------------------------------------------------------

    def _complete(self, req: SimRequest, name: str, body, per_chunk: float,
                  source: str, t_done: float, batch_id):
        if isinstance(req.app, isa.Trace):
            runtime = speedup = float("nan")
        else:
            runtime = suite.vector_runtime_from_per_chunk(
                name, req.cfg, body, per_chunk)
            speedup = suite.scalar_runtime_ns(name, req.cfg) / runtime
        if source == "cache":
            self.n_hits += 1
        res = SimResult(
            uid=req.uid, app=name, label=req.cfg.label(),
            steady_ns=per_chunk, runtime_ns=runtime, speedup=speedup,
            source=source, t_arrival=req.t_arrival, t_done=t_done,
            latency_s=max(t_done - req.t_arrival, 0.0), batch_id=batch_id)
        self.completed.append(res)
        self._results[req.uid] = res
        self.lat_hist.add(res.latency_s)
        if self.snapshot_every and not len(self.completed) % self.snapshot_every:
            self.snapshots.append(telemetry.snapshot_row(
                "serve.snapshot", t=t_done, **self.stats()))
        return res

    def result_for(self, uid: int) -> SimResult | None:
        return self._results.get(uid)

    def stats(self) -> dict:
        """Counter snapshot (JSON-able), including the bounded latency
        histogram with its p50/p99/p99.9 estimates."""
        return {
            "requests": self.n_requests, "hits": self.n_hits,
            "coalesced": self.n_coalesced, "dispatched": self.n_dispatched,
            "shed": self.n_shed, "serialized": self.n_serialized,
            "batches": self.n_batches, "recompiles": self.recompiles,
            "pending": self._waiting,
            "hit_fraction": self.n_hits / self.n_requests
            if self.n_requests else 0.0,
            "cache_entries": len(self.cache),
            "latency": self.lat_hist.to_dict(),
        }


# --------------------------------------------------------------------------
# workloads: deterministic Poisson arrival streams
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Arrival:
    t: float                    # offset from workload start (s)
    app: str
    cfg: eng.VectorEngineConfig


def poisson_arrivals(n: int, rate_hz: float, apps, cfgs,
                     seed: int = 0) -> list[Arrival]:
    """``n`` requests with exponential inter-arrival gaps at ``rate_hz``,
    apps and configs drawn uniformly — fully deterministic in ``seed``, so a
    repeat pass re-issues the identical request stream (the >= 99 %-hits
    acceptance check).

    >>> a = poisson_arrivals(4, 100.0, ("blackscholes",),
    ...                      (eng.VectorEngineConfig(),), seed=7)
    >>> a == poisson_arrivals(4, 100.0, ("blackscholes",),
    ...                       (eng.VectorEngineConfig(),), seed=7)
    True
    >>> [x.t for x in a] == sorted(x.t for x in a)
    True
    """
    apps = tuple(apps)
    cfgs = tuple(cfgs)
    rng = np.random.RandomState(seed)
    ts = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    ia = rng.randint(0, len(apps), size=n)
    ic = rng.randint(0, len(cfgs), size=n)
    return [Arrival(float(t), apps[a], cfgs[c])
            for t, a, c in zip(ts, ia, ic)]


@dataclass
class ServeReport:
    """One workload run through the service, reduced to the serving metrics
    the acceptance criteria name."""
    n: int
    wall_s: float
    throughput_rps: float       # sustained completed-requests/sec
    p50_ms: float
    p99_ms: float
    p999_ms: float              # from the bounded histogram, not raw records
    mean_ms: float
    hits: int
    coalesced: int
    dispatched: int
    batches: int
    shed: int
    recompiles: int
    hit_fraction: float
    latency_hist: dict          # telemetry.LatencyHistogram row (this run)
    results: list               # [SimResult] in completion order

    def to_dict(self) -> dict:
        d = {k: getattr(self, k) for k in (
            "n", "wall_s", "throughput_rps", "p50_ms", "p99_ms", "p999_ms",
            "mean_ms", "hits", "coalesced", "dispatched", "batches", "shed",
            "recompiles", "hit_fraction", "latency_hist")}
        return d


def run_workload(service: SimService, arrivals, realtime: bool = False
                 ) -> ServeReport:
    """Drive an arrival stream through the service.

    ``realtime=True`` sleeps out the true inter-arrival gaps and fires the
    per-batch timeout at its wall-clock deadline, so latency percentiles are
    honest queueing + dispatch measurements (each request's arrival stamp is
    its *scheduled* time — time the service spends busy counts against it).
    ``realtime=False`` submits back-to-back (batches still cut at
    ``max_batch``) for deterministic, fast CI runs.
    """
    arrivals = list(arrivals)
    n0 = len(service.completed)
    s0 = service.stats()
    h0 = service.lat_hist.snapshot()
    t0 = service.clock()
    if realtime:
        for a in arrivals:
            target = t0 + a.t
            while True:
                dl = service.next_deadline()
                nxt = target if dl is None else min(target, dl)
                now = service.clock()
                if now < nxt:
                    time.sleep(nxt - now)
                    now = service.clock()
                if dl is not None and dl <= target and now >= dl:
                    service.flush(now=now)    # per-batch timeout fired
                    continue
                break
            service.submit(a.app, a.cfg, now=target)
    else:
        for a in arrivals:
            service.submit(a.app, a.cfg)
    service.drain()
    wall = service.clock() - t0
    s1 = service.stats()
    results = service.completed[n0:]
    lat = np.array([r.latency_s for r in results]) if results else np.zeros(1)
    hist = service.lat_hist.since(h0)   # just this run's completions
    n_done = len(results)
    return ServeReport(
        n=len(arrivals), wall_s=wall,
        throughput_rps=n_done / wall if wall > 0 else float("inf"),
        p50_ms=float(np.percentile(lat, 50)) * 1e3,
        p99_ms=float(np.percentile(lat, 99)) * 1e3,
        p999_ms=hist.percentile(0.999) * 1e3,
        mean_ms=float(lat.mean()) * 1e3,
        hits=s1["hits"] - s0["hits"],
        coalesced=s1["coalesced"] - s0["coalesced"],
        dispatched=s1["dispatched"] - s0["dispatched"],
        batches=s1["batches"] - s0["batches"],
        shed=s1["shed"] - s0["shed"],
        recompiles=s1["recompiles"] - s0["recompiles"],
        hit_fraction=(s1["hits"] - s0["hits"]) / max(len(arrivals), 1),
        latency_hist=hist.to_dict(),
        results=results)


# --------------------------------------------------------------------------
# CLI / smoke gate
# --------------------------------------------------------------------------

def _default_workload(n: int, rate_hz: float, seed: int, apps=None):
    from repro.configs import vector_engine as vcfg
    apps = tuple(apps) if apps else ("blackscholes", "canneal")
    cfgs = tuple(vcfg.SPACE_SMOKE.sample(8, seed=seed + 1))
    return poisson_arrivals(n, rate_hz, apps, cfgs, seed=seed)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cache", default=None, help="JSONL ResultCache path")
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--rate", type=float, default=400.0,
                    help="Poisson arrival rate (requests/sec)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--apps", default=None,
                    help="comma-separated app subset")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--realtime", action="store_true",
                    help="sleep out true inter-arrival gaps (honest latency)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: prewarmed Poisson run must not recompile; "
                         "a repeat pass against the persisted cache must be "
                         ">=99%% hits with bitwise-identical times")
    args = ap.parse_args(argv)
    apps = tuple(args.apps.split(",")) if args.apps else None
    arrivals = _default_workload(args.n, args.rate, args.seed, apps)

    svc = SimService(cache=dse.ResultCache(args.cache),
                     max_batch=args.max_batch)
    svc.prewarm()
    rep = run_workload(svc, arrivals, realtime=args.realtime)
    print(f"pass 1: {rep.n} requests in {rep.wall_s:.2f}s "
          f"({rep.throughput_rps:.1f} req/s) p50={rep.p50_ms:.2f}ms "
          f"p99={rep.p99_ms:.2f}ms hits={rep.hits} "
          f"coalesced={rep.coalesced} dispatched={rep.dispatched} "
          f"batches={rep.batches} recompiles={rep.recompiles}")
    if not args.smoke:
        return 0

    # repeat pass: a fresh service + a fresh cache object (re-read from disk
    # when --cache was given — the persistence claim)
    svc2 = SimService(cache=dse.ResultCache(args.cache) if args.cache
                      else svc.cache, max_batch=args.max_batch)
    rep2 = run_workload(svc2, arrivals, realtime=False)
    by_uid1 = sorted(rep.results, key=lambda r: r.uid)
    by_uid2 = sorted(rep2.results, key=lambda r: r.uid)
    bitwise = all(a.steady_ns == b.steady_ns and a.app == b.app
                  for a, b in zip(by_uid1, by_uid2))
    ok_recompiles = rep.recompiles == 0
    ok_hits = rep2.hit_fraction >= 0.99
    print(f"pass 2: hit_fraction={rep2.hit_fraction:.1%} "
          f"dispatched={rep2.dispatched} "
          f"times {'bitwise-identical' if bitwise else 'DIVERGED'}; "
          f"pass-1 steady-state recompiles={rep.recompiles} "
          f"-> {'ok' if ok_recompiles and ok_hits and bitwise else 'FAIL'}")
    return 0 if (ok_recompiles and ok_hits and bitwise) else 1


if __name__ == "__main__":
    from repro.serve import sim_service as _canonical
    raise SystemExit(_canonical.main())
