"""Serving layers: LLM continuous batching (`engine`) and
simulation-as-a-service over the vector-engine timing model
(`sim_service`).

Submodules are imported lazily so ``python -m repro.serve.sim_service``
doesn't double-import the module it is executing, and importing one layer
doesn't pay for the other.
"""
_EXPORTS = {
    "Request": "engine", "ServeEngine": "engine", "serve_batch": "engine",
    "Arrival": "sim_service", "ServeReport": "sim_service",
    "SimRequest": "sim_service", "SimResult": "sim_service",
    "SimService": "sim_service", "poisson_arrivals": "sim_service",
    "run_workload": "sim_service",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.serve.{mod}"), name)
