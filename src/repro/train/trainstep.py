"""Train/serve step builders: sharded jit with logical-axis in/out shardings.

``build_train_step`` returns (step_fn, in_shardings, out_shardings) ready for
``jax.jit(...).lower(...)`` in the dry-run or real execution in the trainer.
Gradient accumulation (microbatching) is a ``lax.scan`` over the leading
microbatch split; optional int8 error-feedback gradient compression hooks in
between grad and optimizer (repro.distributed.compression).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding as shd
from repro.models import api as mapi
from repro.train import optimizer as opt


def _shardings_for(tree_structs, tree_logical, mesh):
    return jax.tree.map(
        lambda sd, lg: shd.named_sharding(lg, sd.shape, mesh),
        tree_structs, tree_logical,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def batch_shardings(cfg, shape, mesh):
    specs = mapi.input_specs(cfg, shape)
    logical = mapi.batch_logical(cfg, shape)
    return {k: shd.named_sharding(logical[k], specs[k].shape, mesh) for k in specs}


def opt_structs(param_structs):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return opt.OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(f32, param_structs),
        nu=jax.tree.map(f32, param_structs),
    )


def param_shardings(model, mesh):
    return _shardings_for(model.param_structs(), model.param_logical(), mesh)


def opt_shardings(model, mesh):
    ps = param_shardings(model, mesh)
    return opt.OptState(
        step=shd.named_sharding((), (), mesh),
        mu=ps, nu=ps,
    )


def default_microbatches(cfg: ModelConfig, shape: InputShape, mesh) -> int:
    """Split the global batch so per-microbatch activations fit ~10 GB/device.

    Empirical fit from dry-runs: peak activation temp ~= 77 bytes x
    tokens_per_device x d_model for a rematted train step (fp32 attention
    intermediates dominate).  Must divide the per-device batch.
    """
    if mesh is None:
        return 1
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = axes.get("data", 1) * axes.get("pod", 1)
    per_dev_batch = max(shape.global_batch // n_data, 1)
    tokens_dev = per_dev_batch * shape.seq_len
    need = 77.0 * tokens_dev * cfg.d_model / 10e9
    if cfg.num_experts:
        # MoE dispatch buffers scale with top-k slots (xe/g/ye are
        # [E, capacity, D]-sized); granite (k=8) needs 8 microbatches where
        # the dense estimate says 1 (measured: 30 GiB -> 4.1 GiB).
        need *= 1 + cfg.experts_per_token
    micro = 1
    while micro < per_dev_batch and need / micro > 1.0:
        micro *= 2
    return micro


def build_train_step(model: mapi.Model, shape: InputShape, mesh,
                     opt_cfg: Optional[opt.OptConfig] = None,
                     microbatches: Optional[int] = None,
                     compress_grads: bool = False):
    """Returns (train_step, in_shardings, out_shardings, donate_argnums)."""
    opt_cfg = opt_cfg or opt.OptConfig()
    cfg = model.cfg
    if microbatches is None:
        microbatches = default_microbatches(cfg, shape, mesh)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    logical = model.param_logical()

    def constrain(g):
        # keep gradients in the parameter sharding (reduce-scatter, not
        # replicate+all-reduce) — see sharding.tree_constraint.
        if mesh is None:
            return g
        return shd.tree_constraint(g, logical, mesh)

    def train_step(params, opt_state, batch):
        with shd.use_mesh(mesh):
            if microbatches > 1:
                def micro(g_acc, mb):
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    return jax.tree.map(jnp.add, g_acc, constrain(g)), l
                split = jax.tree.map(
                    lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                        + x.shape[1:]), batch)
                zeros = constrain(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params))
                grads, losses = jax.lax.scan(micro, zeros, split)
                grads = jax.tree.map(lambda g: g / microbatches, grads)
                loss = losses.mean()
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                grads = constrain(grads)
            if compress_grads:
                from repro.distributed.compression import compress_decompress
                grads = compress_decompress(grads)
            new_params, new_state, metrics = opt.apply(opt_cfg, params, grads, opt_state)
            metrics["loss"] = loss
            return new_params, new_state, metrics

    if mesh is None:
        return train_step, None, None, (0, 1)
    p_sh = param_shardings(model, mesh)
    o_sh = opt_shardings(model, mesh)
    b_sh = batch_shardings(cfg, shape, mesh)
    scalar = shd.named_sharding((), (), mesh)
    out_sh = (p_sh, o_sh, {"loss": scalar, "grad_norm": scalar, "lr": scalar})
    return train_step, (p_sh, o_sh, b_sh), out_sh, (0, 1)


def build_prefill_step(model: mapi.Model, shape: InputShape, mesh):
    cfg = model.cfg

    def prefill_step(params, batch):
        with shd.use_mesh(mesh):
            return model.prefill(params, batch, max_seq=shape.seq_len)

    p_sh = param_shardings(model, mesh)
    b_sh = batch_shardings(cfg, shape, mesh)
    cache_structs = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    c_sh = _shardings_for(cache_structs, model.cache_logical(), mesh)
    logits_sh = shd.named_sharding(("batch", None, "vocab"),
                                   (shape.global_batch, 1, cfg.vocab_size), mesh)
    return prefill_step, (p_sh, b_sh), (logits_sh, c_sh), ()


def build_decode_step(model: mapi.Model, shape: InputShape, mesh):
    cfg = model.cfg
    B = shape.global_batch

    def decode_step(params, cache, tokens, pos):
        with shd.use_mesh(mesh):
            return model.decode_step(params, cache, tokens, pos)

    p_sh = param_shardings(model, mesh)
    cache_structs = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
    c_sh = _shardings_for(cache_structs, model.cache_logical(), mesh)
    t_sh = shd.named_sharding(("batch", None), (B, 1), mesh)
    pos_sh = shd.named_sharding((), (), mesh)
    logits_sh = shd.named_sharding(("batch", None, "vocab"), (B, 1, cfg.vocab_size), mesh)
    return decode_step, (p_sh, c_sh, t_sh, pos_sh), (logits_sh, c_sh), (1,)


def decode_inputs(model: mapi.Model, shape: InputShape):
    """ShapeDtypeStruct stand-ins for decode: (cache, tokens, pos)."""
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, pos
