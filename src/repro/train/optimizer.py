"""AdamW optimizer + LR schedules, pure JAX, shard-friendly.

Moments are fp32 and share the parameter sharding (FSDP keeps optimizer state
distributed).  Global-norm clipping and decoupled weight decay included.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [n[0] for n in new])
    new_m = jax.tree.unflatten(tdef, [n[1] for n in new])
    new_v = jax.tree.unflatten(tdef, [n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
