"""Checkpointing: atomic, manifest-driven, elastic (mesh-independent).

Arrays are stored LOGICALLY (full arrays, one .npy per leaf, zstd-free for
offline portability) plus a JSON manifest with step/config/tree structure.
Because storage is logical, a checkpoint written on a 256-chip mesh restores
onto 512 chips (or one CPU) — the elastic-scaling path.  Writes go to a temp
dir + atomic rename; ``latest`` resolution ignores half-written checkpoints.

At real scale the same layout shards per-host via `jax.experimental
.multihost_utils` gathers; on this container every process sees all shards.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    return names, [v for _, v in flat], treedef


def save(ckpt_dir: str, step: int, params, opt_state, extra: dict | None = None):
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    tree = {"params": params, "opt": opt_state}
    names, leaves, _ = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({"name": name, "file": fn,
                                   "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_params, like_opt, shardings=None):
    """Restore into the structure of (like_params, like_opt); optional
    shardings tree re-lays the arrays out on the current mesh (elastic)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    tree = {"params": like_params, "opt": like_opt}
    _, leaves, treedef = _flatten_with_names(tree)
    assert len(leaves) == len(manifest["leaves"]), "tree structure changed"
    out = []
    sh_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))
                 if shardings is not None else [None] * len(leaves))
    for meta, like, sh in zip(manifest["leaves"], leaves, sh_leaves):
        arr = np.load(os.path.join(d, meta["file"]))
        assert list(arr.shape) == list(like.shape), (meta["name"], arr.shape, like.shape)
        if sh is not None:
            out.append(jax.device_put(arr.astype(like.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    restored = jax.tree.unflatten(treedef, out)
    return restored["params"], restored["opt"], manifest
