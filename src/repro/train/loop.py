"""Fault-tolerant training loop: checkpoint/restart, retry, straggler hooks.

``train`` resumes from the newest valid checkpoint, saves every
``ckpt_every`` steps, retries transient step failures with backoff (the
single-host stand-in for preemption/ICI-flap recovery), and logs per-step
wall time with a deadline-based straggler monitor (at fleet scale the monitor
feeds the scheduler; here it logs).  Elasticity: the checkpoint layout is
mesh-independent (see train/checkpoint.py), so a restart may use a different
device count.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.data import pipeline as dpipe
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train import trainstep


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    max_retries: int = 3
    retry_backoff_s: float = 1.0
    straggler_deadline_factor: float = 3.0
    log_every: int = 10


@dataclass
class LoopState:
    step: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    restarts: int = 0
    straggler_events: int = 0


def train(model, shape, mesh, opt_cfg=None, loop_cfg: LoopConfig | None = None,
          data_seed: int = 0, fail_injector=None) -> LoopState:
    """Run (or resume) training; returns the loop state."""
    loop_cfg = loop_cfg or LoopConfig()
    cfg = model.cfg
    opt_cfg = opt_cfg or opt_mod.OptConfig(total_steps=loop_cfg.total_steps)
    step_fn, in_sh, out_sh, donate = trainstep.build_train_step(
        model, shape, mesh, opt_cfg=opt_cfg)
    jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)

    dcfg = dpipe.DataConfig(cfg.vocab_size, shape.seq_len, shape.global_batch,
                            seed=data_seed)
    state = LoopState()

    # ---- init or resume -----------------------------------------------------
    last = ckpt.latest_step(loop_cfg.ckpt_dir)
    params = model.init(jax.random.key(0))
    opt_state = opt_mod.init(params)
    if last is not None:
        sh = ({"params": in_sh[0], "opt": in_sh[1]} if in_sh is not None else None)
        params, opt_state, manifest = ckpt.restore(
            loop_cfg.ckpt_dir, last, params, opt_state, shardings=sh)
        state.step = manifest["step"]
        state.restarts += 1
    if mesh is not None and in_sh is not None:
        params = jax.device_put(params, in_sh[0])
        opt_state = jax.device_put(opt_state, in_sh[1])

    median_t = None
    while state.step < loop_cfg.total_steps:
        step = state.step
        batch = dpipe.batch_at(dcfg, step)
        batch.update(dpipe.extra_inputs(cfg, shape.global_batch, data_seed, step))
        if cfg.family == "vlm":
            P = cfg.num_patches
            batch["tokens"] = batch["tokens"][:, :shape.seq_len - P]
            batch["labels"] = batch["labels"][:, :shape.seq_len - P]

        for attempt in range(loop_cfg.max_retries + 1):
            try:
                if fail_injector is not None:
                    fail_injector(step, attempt)
                t0 = time.time()
                params, opt_state, metrics = jitted(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                break
            except (RuntimeError, jax.errors.JaxRuntimeError):
                if attempt >= loop_cfg.max_retries:
                    raise
                time.sleep(loop_cfg.retry_backoff_s * (2 ** attempt))
                state.restarts += 1

        state.losses.append(loss)
        state.step_times.append(dt)
        if median_t and dt > loop_cfg.straggler_deadline_factor * median_t:
            state.straggler_events += 1  # fleet: report host to the scheduler
        if len(state.step_times) >= 5:
            median_t = float(np.median(state.step_times[-20:]))
        state.step += 1
        if state.step % loop_cfg.log_every == 0:
            print(f"step {state.step}: loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if state.step % loop_cfg.ckpt_every == 0 or state.step == loop_cfg.total_steps:
            ckpt.save(loop_cfg.ckpt_dir, state.step, params, opt_state,
                      extra={"loss": loss})
    return state
