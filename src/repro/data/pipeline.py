"""Deterministic, seekable synthetic token pipeline.

Fault tolerance requires the data stream to be a pure function of
(seed, step): after a restart the loop resumes at the checkpointed step and
sees exactly the tokens it would have seen — no iterator state to persist.
Sequences follow a Zipf-ish marginal with short-range correlations so losses
move during the example runs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Batch for `step`, deterministically (host-side numpy; cheap)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # zipf-ish marginal over the vocab
    ranks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
    base = (ranks - 1) % V
    # short-range structure: every 4th token repeats an earlier one
    rep = np.roll(base, 3, axis=1)
    mask = (np.arange(S + 1)[None, :] % 4) == 0
    toks = np.where(mask, rep, base).astype(np.int32)
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }


def extra_inputs(cfg_model, batch_size: int, seed: int, step: int) -> dict:
    """Stub modality inputs (frames/patches) for encdec / vlm families."""
    out = {}
    key = jax.random.fold_in(jax.random.key(seed + 1), step)
    if cfg_model.family == "encdec":
        out["frames"] = jax.random.normal(
            key, (batch_size, cfg_model.num_frames, cfg_model.d_model),
            jnp.float32).astype(cfg_model.jnp_dtype)
    if cfg_model.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (batch_size, cfg_model.num_patches, cfg_model.d_model),
            jnp.float32).astype(cfg_model.jnp_dtype)
    return out


def batches(cfg: DataConfig, start_step: int = 0):
    step = start_step
    while True:
        yield step, batch_at(cfg, step)
        step += 1
