"""Manual collective kernels (shard_map) for patterns GSPMD mishandles.

``flash_decode_attention``: single-token decode against a KV cache whose
*sequence* dim is sharded over the model axis.  GSPMD turns the cache update
into a full-cache all-gather (66 GB/step measured for llama3-8b decode_32k),
and scan/unroll both double-buffer it.  Here each shard performs a guarded
local dynamic-update-slice (writes the incoming K/V if `pos` falls in its
range, rewrites the old value otherwise — always a slice-sized write), then a
flash-decode combine: local partial softmax, pmax/psum over the model axis.
This is the paper's ring-interconnect idea applied at pod scale: lane-local
work + a cheap cross-lane combine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import compat_shard_map


def _dp_axes(mesh, batch):
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in axes)
    while dp:
        n = 1
        for a in dp:
            n *= axes[a]
        if batch % n == 0:
            break
        dp = dp[1:]
    return dp


def applicable(mesh, batch, seq, num_heads, num_kv_heads) -> bool:
    if mesh is None:
        return False
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = axes.get("model", 1)
    return seq % ep == 0


def flash_decode_attention(q, cache_k, cache_v, k_new, v_new, pos, mesh):
    """q [B,1,H,hd]; cache [B,S,KV,hd] (seq sharded over "model"); k/v_new
    [B,1,KV,hd]; pos scalar.  Returns (out [B,1,H,hd], cache_k, cache_v)."""
    B, S, KV, hd = cache_k.shape
    H = q.shape[2]
    groups = H // KV
    dp = _dp_axes(mesh, B)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = axes.get("model", 1)
    Sl = S // ep
    scale = hd ** -0.5

    def body(q, ck, cv, kn, vn, pos):
        ax = jax.lax.axis_index("model")
        start = ax * Sl
        loc = pos - start
        in_range = (loc >= 0) & (loc < Sl)
        loc_c = jnp.clip(loc, 0, Sl - 1)
        Bl = ck.shape[0]
        # guarded local in-place update: always write a slice (old value when
        # out of range) so no full-cache select/copy is ever materialized
        old_k = jax.lax.dynamic_slice(ck, (0, loc_c, 0, 0), kn.shape)
        old_v = jax.lax.dynamic_slice(cv, (0, loc_c, 0, 0), vn.shape)
        kw = jnp.where(in_range, kn.astype(ck.dtype), old_k)
        vw = jnp.where(in_range, vn.astype(cv.dtype), old_v)
        ck = jax.lax.dynamic_update_slice(ck, kw, (0, loc_c, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, vw, (0, loc_c, 0, 0))

        # local partial attention over this shard's keys
        kk = ck.astype(q.dtype)
        vv = cv.astype(q.dtype)
        if groups > 1:
            kk = jnp.repeat(kk, groups, axis=-2)
            vv = jnp.repeat(vv, groups, axis=-2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
        gi = start + jnp.arange(Sl)
        s = jnp.where((gi <= pos)[None, None, None, :], s, -jnp.inf)
        m_loc = s.max(-1)
        m = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m[..., None])
        l = jax.lax.psum(p.sum(-1), "model")
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), vv).astype(jnp.float32)
        o = jax.lax.psum(o, "model") / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return o.astype(q.dtype), ck, cv

    out, ck, cv = compat_shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, None, None),
                  P(dp_spec, "model", None, None),
                  P(dp_spec, "model", None, None),
                  P(dp_spec, None, None, None),
                  P(dp_spec, None, None, None),
                  P()),
        out_specs=(P(dp_spec, None, None, None),
                   P(dp_spec, "model", None, None),
                   P(dp_spec, "model", None, None)),
        check_vma=False,
    )(q, cache_k, cache_v, k_new, v_new, jnp.asarray(pos, jnp.int32))
    return out, ck, cv
