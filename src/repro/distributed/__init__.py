from repro.distributed.sharding import (constraint, logical_to_spec,
                                        named_sharding, tree_shardings, use_mesh)
