"""GPipe-style pipeline parallelism over the ``pod`` axis (multi-pod mesh).

The default multi-pod configuration treats ``pod`` as extra data parallelism;
this module provides the alternative: each pod owns half the layer stack and
microbatches stream through a collective-permute ring.  A 1F1B-ish schedule
is emulated with a scan over (microbatches + stages - 1) ticks; bubbles =
(stages-1)/(ticks) as usual.  Exercised by tests and by
``launch/dryrun.py --pipeline`` for one config to prove the lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import compat_shard_map


def pipeline_apply(fn_stage, params_stages, x_micro, mesh, *, stages: int):
    """Run `x_micro` [M, ...] microbatches through `stages` pipeline stages.

    fn_stage(stage_params, x) -> x.  params_stages has a leading [stages] dim
    sharded over "pod"; each pod applies its local stage and permutes
    activations to the next pod between ticks.
    """
    M = x_micro.shape[0]
    ticks = M + stages - 1

    def body(h, params, x_m):
        """One shard (pod) tick: receive, compute local stage, hand off."""
        return fn_stage(params, h)

    def sharded(x_micro, params_stages):
        ax = jax.lax.axis_index("pod")
        out = jnp.zeros_like(x_micro)
        state = jnp.zeros_like(x_micro[0])

        def tick(carry, t):
            state, out = carry
            # stage 0 ingests microbatch t (if in range) — other stages use
            # what arrived over the ring last tick
            m_in = jnp.clip(t, 0, M - 1)
            inject = jnp.where(ax == 0,
                               x_micro[m_in],
                               state)
            y = fn_stage(jax.tree.map(lambda p: p[0], params_stages), inject)
            # last stage emits microbatch t-(stages-1)
            m_out = jnp.clip(t - (stages - 1), 0, M - 1)
            emit = (ax == stages - 1) & (t >= stages - 1)
            out = jnp.where(emit, out.at[m_out].set(y), out)
            # ring hand-off to the next stage
            y_next = jax.lax.ppermute(
                y, "pod", [(i, (i + 1) % stages) for i in range(stages)])
            return (y_next, out), None

        (_, out), _ = jax.lax.scan(tick, (state, out), jnp.arange(ticks))
        # the final outputs live on the last pod; share them
        out = jax.lax.psum(out, "pod") / 1.0  # all pods but last contribute 0
        return out

    return compat_shard_map(
        sharded, mesh=mesh,
        in_specs=(P(), P("pod")),
        out_specs=P(),
        check_vma=False,
    )(x_micro, params_stages)
