"""Logical-axis sharding rules with divisibility-aware fallback.

Model code annotates every parameter/activation with a tuple of *logical axis*
names (e.g. ``("layers", "embed", "heads")``).  :func:`logical_to_spec` resolves
those names against the active mesh through a rule table, dropping any mesh axis
that does not evenly divide the corresponding dimension (GSPMD rejects uneven
*input* shardings, so the fallback is replication on that axis — recorded in
DESIGN.md §5 for qwen1.5-32b / whisper / granite).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, tuple, None]

# Default logical->mesh rules.  "fsdp" and "tp" are *roles* resolved per mesh:
#   single pod : fsdp=("data",)      tp=("model",)
#   multi-pod  : fsdp=("pod","data") tp=("model",)   (pod as extra DP/FSDP dim)
# Activations use "batch" (data-parallel) and "seq_sp" (sequence parallelism
# over the tp axis between blocks).
LOGICAL_RULES: dict[str, str] = {
    # parameters
    "embed": "fsdp",         # d_model dim of weights: FSDP-sharded
    "heads": "tp",
    "kv_heads": "tp",
    "qkv": "tp",             # fused qkv output dim
    "ff": "tp",
    "vocab": "tp",
    "expert": "ep",          # expert axis (EP); falls back per-expert TP via "expert_ff"
    "expert_ff": "tp",
    "moe_cap": "dp_tp",      # MoE capacity dim: data axis (+ model when EP unused)
    "ssm_heads": "tp",
    "ssm_inner": "tp",
    "ssm_state": None,
    "layers": None,
    "stack": None,
    # activations
    "batch": "dp",
    "seq": None,
    "seq_sp": "tp",          # sequence-parallel activations between blocks
    "seq_kv": "tp",          # KV-cache sequence dim for long-context decode
    "act_embed": None,
    "frames": None,
}


def mesh_roles(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    names = mesh.axis_names
    multi = "pod" in names
    dp = ("pod", "data") if multi else ("data",)
    return {
        "dp": dp,
        "fsdp": dp,
        "tp": ("model",),
        "ep": ("model",),
        "dp_tp": dp + ("model",),
    }


def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_to_spec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Mapping[str, str]] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec, honoring divisibility."""
    rules = dict(LOGICAL_RULES, **(rules or {}))
    roles = mesh_roles(mesh)
    used: set[str] = set()
    spec: list[Axis] = []
    assert len(logical) == len(shape), (logical, shape)
    for name, dim in zip(logical, shape):
        role = rules.get(name) if name else None
        if role is None:
            spec.append(None)
            continue
        axes = roles[role]
        # never map the same mesh axis to two tensor dims
        axes = tuple(a for a in axes if a not in used)
        if not axes or dim % _axis_size(mesh, axes) != 0:
            # try a prefix that still divides (e.g. drop "pod" but keep "data")
            while axes and dim % _axis_size(mesh, axes) != 0:
                axes = axes[1:]
            if not axes:
                spec.append(None)
                continue
        used.update(axes)
        spec.append(axes[0] if len(axes) == 1 else tuple(axes))
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def named_sharding(logical, shape, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical, shape, mesh, rules))


def tree_shardings(logical_tree, shape_tree, mesh, rules=None):
    """Map a pytree of logical-axis tuples + matching ShapeDtypeStructs to shardings."""
    return jax.tree.map(
        lambda lg, sd: named_sharding(lg, sd.shape, mesh, rules),
        logical_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def _is_logical_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def tree_constraint(tree, logical_tree, mesh, rules=None):
    """with_sharding_constraint a whole pytree by a parallel logical-axis tree.

    Used on gradient trees: without it XLA may materialize full-size replicated
    gradients (observed: 1.6 GB f32 embedding grads all-reduced per microbatch)
    instead of reduce-scattering into the parameter sharding.
    """
    leaves, tdef = jax.tree.flatten(tree)
    logical = jax.tree.leaves(logical_tree, is_leaf=_is_logical_leaf)
    assert len(leaves) == len(logical), (len(leaves), len(logical))
    out = [
        jax.lax.with_sharding_constraint(
            x, named_sharding(lg, x.shape, mesh, rules))
        for x, lg in zip(leaves, logical)
    ]
    return jax.tree.unflatten(tdef, out)


# --- version compat ----------------------------------------------------------

def compat_shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on any installed JAX: newer releases expose it at the
    top level with a ``check_vma`` flag, older ones only have
    ``jax.experimental.shard_map.shard_map`` with the equivalent flag spelled
    ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


# --- active-mesh context -----------------------------------------------------
# Model code calls constraint(x, logical) without threading a mesh through every
# layer; the step builders (train/serve/dryrun) install the mesh here.  When no
# mesh is active (unit tests on one device) constraints are a no-op.

_ACTIVE_MESH: list[Optional[Mesh]] = [None]


class use_mesh:
    """Context manager installing the active mesh for logical constraints."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        _ACTIVE_MESH.append(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _ACTIVE_MESH.pop()


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH[-1]


def constraint(x, logical, mesh=None, rules=None):
    """with_sharding_constraint by logical axes (no-op when no mesh active)."""
    mesh = mesh or active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(logical, x.shape, mesh, rules))
