"""int8 error-feedback gradient compression.

At 1000-node scale the gradient reduce-scatter competes with the FSDP
all-gathers for ICI; 4x-compressing gradients (bf16/f32 -> int8 with a per-
tensor scale) cuts that term.  Error feedback (residual carried to the next
step) keeps SGD convergence (1-bit Adam lineage).  ``compress_decompress`` is
the in-graph quantize/dequantize used by the train step when
``compress_grads=True``; with shard_map the quantized payload is what crosses
the ICI (XLA reduces the int8-scaled values).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x):
    """x -> (int8 q, f32 scale); per-tensor symmetric."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_decompress(grads, residuals=None):
    """Quantize/dequantize every leaf (optionally with error feedback).

    Returns grads' (and residuals' when residuals are provided).
    """
    if residuals is None:
        def f(g):
            q, s = quantize(g)
            return dequantize(q, s, g.dtype)
        return jax.tree.map(f, grads)

    def f(g, r):
        gc = g.astype(jnp.float32) + r
        q, s = quantize(gc)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), gc - deq

    out = jax.tree.map(f, grads, residuals)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_r
