"""TPU three-term roofline model (the paper's VAO analysis, generalized).

The paper predicts vector-engine speedups from instruction counts alone (VAO
speedup, §4.1); on TPU the equivalent first-order model is the three-term
roofline computed from the compiled dry-run artifact:

    compute    = HLO_FLOPs / peak_FLOPs            (per device)
    memory     = HLO_bytes / HBM_bandwidth          (per device)
    collective = ICI_bytes / ICI_bandwidth          (per device)

The dominant term is the bottleneck; step time >= max(terms); the "roofline
fraction" we hillclimb is useful_model_flops_time / max(terms).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # bytes/s
    ici_bw: float = 50e9            # bytes/s per link (1 link assumed in use)
    hbm_bytes: float = 16e9         # capacity


V5E = Chip()


@dataclass
class Roofline:
    flops: float                # per-device HLO flops
    hbm_bytes: float            # per-device HLO bytes accessed
    ici_bytes: float            # per-device collective bytes
    model_flops: float          # useful (6ND-style) flops, GLOBAL
    chips: int
    chip: Chip = V5E

    @property
    def t_compute(self) -> float:
        return self.flops / self.chip.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.chip.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.ici_bytes / self.chip.ici_bw

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops): how much compiled compute is useful."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline fraction: useful-flops time / bound time (per device)."""
        t_useful = self.model_flops / self.chips / self.chip.peak_flops
        return t_useful / self.t_bound if self.t_bound else 0.0

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound": self.bound,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.mfu_bound,
        }


def model_flops(cfg, shape) -> float:
    """Useful FLOPs: 6·N·D train, 2·N·D inference (N = active params)."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def _attn_params(cfg) -> float:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return d * H * hd * 2 + d * KV * hd * 2


def _ssd_params(cfg) -> float:
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    return D * DI * 2 + 2 * D * N + D * H + DI * D + (DI + 2 * N) * 4


def active_params(cfg) -> float:
    """Parameters touched per token (MoE counts top-k experts only)."""
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "dense" or cfg.family == "vlm":
        per = _attn_params(cfg) + 3 * d * cfg.d_ff
        return emb + cfg.num_layers * per
    if cfg.family == "moe":
        per = _attn_params(cfg) + 3 * d * cfg.d_ff * cfg.experts_per_token
        return emb + cfg.num_layers * per
    if cfg.family == "ssm":
        return emb + cfg.num_layers * _ssd_params(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import layout
        total = 0.0
        for mixer, ffn in layout(cfg):
            total += _attn_params(cfg) if mixer == "attn" else _ssd_params(cfg)
            total += 3 * d * cfg.d_ff * (cfg.experts_per_token if ffn == "moe" else 1)
        return emb + (cfg.num_layers // cfg.attn_period) * total
    if cfg.family == "encdec":
        enc = cfg.encoder_layers * (_attn_params(cfg) + 3 * d * cfg.d_ff)
        dec = cfg.num_layers * (2 * _attn_params(cfg) + 3 * d * cfg.d_ff)
        return emb + enc + dec
    raise ValueError(cfg.family)


def total_params(cfg) -> float:
    """All parameters (MoE counts every expert)."""
    if cfg.family == "moe":
        d = cfg.d_model
        per = _attn_params(cfg) + 3 * d * cfg.d_ff * cfg.num_experts
        emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
        return emb + cfg.num_layers * per
    if cfg.family == "hybrid":
        from repro.models.hybrid import layout
        d = cfg.d_model
        emb = cfg.vocab_size * d
        total = 0.0
        for mixer, ffn in layout(cfg):
            total += _attn_params(cfg) if mixer == "attn" else _ssd_params(cfg)
            total += 3 * d * cfg.d_ff * (cfg.num_experts if ffn == "moe" else 1)
        return emb + (cfg.num_layers // cfg.attn_period) * total
    return active_params(cfg)
