"""Jaxpr → vector-IR trace frontend: lower JAX kernel bodies to engine traces.

The suite's second trace source.  The hand-coded bodies in
``repro.core.tracegen`` describe each application's loop body as an explicit
instruction list; this module derives the same ``isa.Trace`` mechanically
from a *traced JAX function* — one MVL-chunk worth of the kernel's work —
so any jax-expressible kernel becomes a simulatable benchmark:

1. the chunk function is traced to a jaxpr (``jax.make_jaxpr``),
2. every equation is mapped to vector IR (the table below),
3. logical vector registers are assigned by live range (linear scan over
   the 32-register file the engine scoreboard models),
4. loads/stores come from declared :class:`Stream` block specs, carrying
   the stream's ``footprint_kb`` and access pattern so the analytic memory
   model (``repro.core.memory``) works unchanged.

Primitive → IR mapping (``docs/architecture.md`` has the full table):

=====================================  =====================================
jaxpr primitive                        vector IR
=====================================  =====================================
add/sub/min/max/compare/select/...     ``VARITH`` @ ``FU_SIMPLE``
mul / integer_pow / square             ``VARITH`` @ ``FU_MUL``
div / sqrt / rsqrt / rem               ``VARITH`` @ ``FU_DIV``
exp / log / erf / tanh / sin / ...     ``VARITH`` @ ``FU_TRANS``
reduce_sum/max/min/prod                ``VREDUCE`` (result stays vector-
                                       register resident, RVV ``vfred*``)
reduce_or/and, argmax/argmin           ``VMASK_SCALAR`` (``vfirst``/``vpopc``
                                       class: result goes to the scalar core)
roll / concatenate / pad               ``VSLIDE`` (lane interconnect)
cumsum/cumprod/cummax/cummin           ``ceil(log2(vl))`` × (``VSLIDE`` +
                                       ``VARITH``) — the RVV prefix ladder
gather (``x[idx]``)                    ``VLOAD`` @ ``MEM_INDEXED``
declared :class:`Stream` in/outs       ``VLOAD``/``VSTORE`` with the
                                       stream's pattern and footprint
rank-0 equations                       coalesced ``SCALAR_BLOCK``; marked
                                       ``dep_scalar`` when they consume a
                                       vector-engine result (reduction /
                                       mask / element extract)
broadcast/reshape/convert/slice/...    free (register-view bookkeeping)
=====================================  =====================================

Constructs with no JAX-level analogue — whole-register spill moves and the
``vfirst.m``/``vpopc.m`` mask round trips — are declared explicitly in the
kernel spec (:class:`RawRecords`), and bulk scalar bookkeeping is declared
as :class:`ScalarWork`; everything vectorizable is derived from the jaxpr.

``cross_validate`` is the contract that keeps the two frontends honest: for
every RiVec app carrying a ``kernel=`` spec, the derived body must match the
hand-coded one exactly on instruction-kind mix, FU mix, memory-pattern mix,
element counts and scalar work, stay within the register file, and agree on
steady-state time within ``TIME_RTOL`` (5%).  ``python -m
repro.core.frontend`` runs the gate (wired into ``scripts/ci.sh``).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crossval, isa

try:  # the public home since jax 0.4.x; jax.core kept as fallback
    from jax.extend.core import Literal as _Literal
except Exception:  # pragma: no cover
    from jax.core import Literal as _Literal


class FrontendError(Exception):
    """A kernel uses a primitive (or a value shape) the frontend can't map."""


# --------------------------------------------------------------------------
# primitive classification tables
# --------------------------------------------------------------------------

_S, _M, _D, _T = isa.FU_SIMPLE, isa.FU_MUL, isa.FU_DIV, isa.FU_TRANS

FU_OF_PRIM = {
    "add": _S, "add_any": _S, "sub": _S, "max": _S, "min": _S, "neg": _S,
    "abs": _S, "and": _S, "or": _S, "xor": _S, "not": _S, "gt": _S, "lt": _S,
    "ge": _S, "le": _S, "eq": _S, "ne": _S, "select_n": _S, "sign": _S,
    "floor": _S, "ceil": _S, "round": _S, "clamp": _S, "is_finite": _S,
    "shift_left": _S, "shift_right_logical": _S, "shift_right_arithmetic": _S,
    "mul": _M, "integer_pow": _M, "square": _M,
    "div": _D, "sqrt": _D, "rsqrt": _D, "rem": _D,
    "exp": _T, "exp2": _T, "log": _T, "log2": _T, "log1p": _T, "expm1": _T,
    "erf": _T, "erfc": _T, "erf_inv": _T, "sin": _T, "cos": _T, "tan": _T,
    "asin": _T, "acos": _T, "atan": _T, "atan2": _T, "sinh": _T, "cosh": _T,
    "tanh": _T, "logistic": _T, "pow": _T, "cbrt": _T,
}

REDUCE_FU = {"reduce_sum": _S, "reduce_max": _S, "reduce_min": _S,
             "reduce_prod": _M}

MASK_PRIMS = ("reduce_or", "reduce_and", "argmax", "argmin")

CUMULATIVE_FU = {"cumsum": _S, "cummax": _S, "cummin": _S, "cumprod": _M,
                 "cumlogsumexp": _T}

SLIDE_PRIMS = ("concatenate", "pad", "rev")

# register-view / layout bookkeeping: free at the IR level
SKIP_PRIMS = ("convert_element_type", "broadcast_in_dim", "reshape",
              "squeeze", "expand_dims", "slice", "transpose", "iota",
              "stop_gradient", "copy", "device_put", "bitcast_convert_type")

CALL_PRIMS = ("pjit", "closed_call", "core_call", "custom_jvp_call",
              "custom_vjp_call", "remat", "checkpoint")

# the contract constants live in the shared cross-validation harness
# (repro.core.crossval); re-exported here for compatibility
N_LOGICAL_REGS = crossval.N_LOGICAL_REGS
TIME_RTOL = crossval.TIME_RTOL


# --------------------------------------------------------------------------
# kernel specs: streams + segments
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Stream:
    """A declared memory stream (the frontend's block spec): name, working-set
    footprint between reuses (KB, feeds the analytic memory model), and
    access pattern."""
    name: str
    footprint_kb: float
    pattern: int = isa.MEM_UNIT


@dataclass(frozen=True)
class KernelBody:
    """A traced-JAX segment of a chunk: ``fn`` is traced at vector length
    ``vl``; ``ins`` are :class:`Stream` block specs (lowered to ``VLOAD``)
    or names of values produced by earlier segments; ``outs`` pair the fn's
    return values with :class:`Stream` specs (lowered to ``VSTORE``), names
    (kept live for later segments), or ``None`` (dropped).

    ``lazy_loads=False`` fetches every declared block up front (Pallas
    block-spec semantics); ``True`` issues each load at first use (RVV
    streaming codegen) — required when a segment declares more streams than
    the register file holds."""
    fn: Callable
    vl: int
    ins: tuple = ()
    outs: tuple = ()
    lazy_loads: bool = False


@dataclass(frozen=True)
class ScalarWork:
    """Declared scalar-core bookkeeping (loop/addressing overhead): the
    per-chunk instruction counts come from the app characterization, not
    from the jaxpr."""
    count: float
    fu: int = isa.FU_SIMPLE
    dep_scalar: bool = False


@dataclass(frozen=True)
class RawRecords:
    """Escape hatch for IR constructs with no JAX analogue (spill moves,
    ``vfirst``/``vpopc`` mask round trips): explicit record dicts."""
    records: tuple


# --------------------------------------------------------------------------
# the characterized arithmetic chain (shared sequence with tracegen)
# --------------------------------------------------------------------------

def chain_ops(n: int, mix: dict, seeds=(1.0,), vl: int = 8,
              window: int = 16) -> list:
    """Apply ``n`` arithmetic ops in the canonical characterized sequence
    (``isa.fu_sequence`` — the same FU mix and shuffle the hand-coded bodies
    use) over a rotating dependency window of jnp values; returns the final
    window.

    Float seeds become dependency-free immediates (splats), mirroring the
    hand-coded bodies' constant-ready rotating registers; jnp-array seeds
    (e.g. loaded stream values) create real operand dependencies.
    """
    vals = [jnp.full((vl,), float(s), jnp.float32)
            if isinstance(s, (int, float)) else s for s in seeds]
    if not vals:
        raise FrontendError("chain_ops needs at least one seed")
    win = [vals[i % len(vals)] for i in range(window)]
    extra = list(vals[window:])
    for i, cls in enumerate(isa.fu_sequence(n, mix)):
        a = win[(i + 5) % window]
        b = extra.pop(0) if (extra and cls != isa.FU_TRANS) \
            else win[(i + 11) % window]
        if cls == isa.FU_SIMPLE:
            r = a + b
        elif cls == isa.FU_MUL:
            r = a * b
        elif cls == isa.FU_DIV:
            r = a / b
        else:
            r = jnp.exp(a)
        win[i % window] = r
    return win


# --------------------------------------------------------------------------
# phase 1: walk segments/jaxprs into a linear vop list
# --------------------------------------------------------------------------

@dataclass
class _Val:
    """Abstract value during the walk: a vector register candidate ('vec',
    with a token), a scalar-core value ('sca'), or an immediate ('imm').
    ``hot`` marks scalar values produced by the vector engine — their scalar
    consumers become ``dep_scalar`` blocks."""
    kind: str
    tok: int = -1
    hot: bool = False


_IMM = _Val("imm")


class _Walker:
    def __init__(self):
        self.ops: list[dict] = []
        self.n_tok = 0
        self.env: dict[str, int] = {}
        self.stream_of_tok: dict[int, Stream] = {}
        self._pending = None           # coalescing SCALAR_BLOCK
        self._lazy: dict[int, dict] = {}

    def tok(self) -> int:
        self.n_tok += 1
        return self.n_tok - 1

    # -- record emission ----------------------------------------------------
    def _flush(self):
        if self._pending is not None:
            self.ops.append(self._pending)
            self._pending = None

    def scalar_eqn(self, dep: bool):
        if self._pending is None:
            self._pending = {"op": "scalar", "count": 0, "fu": isa.FU_SIMPLE,
                             "dep": False}
        self._pending["count"] += 1
        self._pending["dep"] |= dep

    def emit(self, op: dict):
        """Append a vector op (flushing any pending scalar block first)."""
        self._flush()
        self.ops.append(op)

    def use(self, val: _Val) -> int:
        """Resolve a vec value to its token, materializing a lazy load."""
        pend = self._lazy.pop(val.tok, None)
        if pend is not None:
            self.emit(pend)
        return val.tok

    # -- jaxpr walk ---------------------------------------------------------
    def walk(self, jaxpr, valmap: dict, vl: int):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in CALL_PRIMS:
                self._walk_call(eqn, valmap, vl)
                continue
            invals = [self._resolve(v, valmap) for v in eqn.invars]
            out = eqn.outvars[0]
            oshape = getattr(out.aval, "shape", ())
            onelem = int(np.prod(oshape)) if oshape else 1
            vecs = [v for v in invals if v.kind == "vec"]

            if name in SKIP_PRIMS:
                valmap[out] = self._skip_val(invals, oshape)
            elif name in CUMULATIVE_FU:
                valmap[out] = self._cumulative(name, invals, onelem)
            elif name in REDUCE_FU:
                in_elems = int(np.prod(eqn.invars[0].aval.shape))
                t = self.tok()
                self.emit({"op": "reduce", "vl": in_elems,
                           "fu": REDUCE_FU[name],
                           "src": self.use(vecs[0]) if vecs else None,
                           "out": t})
                # result stays vector-register resident (RVV vfred*) but is
                # hot: a scalar consumer needs the engine's scalar result
                valmap[out] = _Val("vec", t, hot=True)
                for ov in eqn.outvars[1:]:
                    valmap[ov] = _Val("sca", hot=True)
            elif name in MASK_PRIMS:
                in_elems = int(np.prod(eqn.invars[0].aval.shape))
                self.emit({"op": "mask", "vl": in_elems,
                           "src": self.use(vecs[0]) if vecs else None})
                for ov in eqn.outvars:
                    valmap[ov] = _Val("sca", hot=True)
            elif name == "gather":
                stream = self.stream_of_tok.get(
                    invals[0].tok if invals[0].kind == "vec" else -1)
                fp = stream.footprint_kb if stream else 64.0
                idx = invals[1] if len(invals) > 1 else _IMM
                t = self.tok()
                self.emit({"op": "load", "vl": onelem, "out": t,
                           "stream": Stream("gather", fp, isa.MEM_INDEXED),
                           "idx": self.use(idx) if idx.kind == "vec" else None})
                valmap[out] = _Val("vec", t)
            elif name in SLIDE_PRIMS:
                t = self.tok()
                self.emit({"op": "slide", "vl": onelem,
                           "src": self.use(vecs[0]) if vecs else None,
                           "out": t})
                valmap[out] = _Val("vec", t)
            elif name in FU_OF_PRIM:
                if not oshape:  # rank-0: runs on the scalar core
                    dep = any(v.hot or v.kind == "vec" for v in invals)
                    self.scalar_eqn(dep)
                    valmap[out] = _Val("sca", hot=dep)
                else:
                    t = self.tok()
                    srcs = [self.use(v) for v in vecs]
                    self.emit({"op": "arith", "vl": onelem,
                               "fu": FU_OF_PRIM[name], "srcs": srcs,
                               "out": t, "n_src": len(srcs)})
                    valmap[out] = _Val("vec", t)
            else:
                raise FrontendError(
                    f"no vector-IR mapping for primitive {name!r} "
                    f"(see frontend.FU_OF_PRIM and friends)")

    def _resolve(self, v, valmap) -> _Val:
        if isinstance(v, _Literal):
            return _IMM
        try:
            return valmap[v]
        except KeyError:
            raise FrontendError(f"unbound jaxpr variable {v}") from None

    def _skip_val(self, invals, oshape) -> _Val:
        vecs = [v for v in invals if v.kind == "vec"]
        if vecs and not oshape:
            # element extract (vector -> scalar): a vfmv.f.s-class transfer
            return _Val("sca", hot=True)
        if vecs:
            return vecs[0]           # register view, aliases the operand
        if any(v.kind == "sca" for v in invals):
            return _Val("sca", hot=any(v.hot for v in invals))
        return _IMM

    def _cumulative(self, name, invals, nelem) -> _Val:
        """RVV prefix ladder: ceil(log2(vl)) rounds of slide + op."""
        cur = invals[0]
        rounds = max(1, int(math.ceil(math.log2(max(nelem, 2)))))
        for _ in range(rounds):
            ts = self.tok()
            self.emit({"op": "slide", "vl": nelem,
                       "src": self.use(cur) if cur.kind == "vec" else None,
                       "out": ts})
            ta = self.tok()
            srcs = ([self.use(cur)] if cur.kind == "vec" else []) + [ts]
            self.emit({"op": "arith", "vl": nelem, "fu": CUMULATIVE_FU[name],
                       "srcs": srcs, "out": ta, "n_src": len(srcs)})
            cur = _Val("vec", ta)
        return cur

    def _walk_call(self, eqn, valmap, vl):
        p = eqn.params
        inner = next((p[k] for k in ("jaxpr", "call_jaxpr", "fun_jaxpr")
                      if k in p), None)
        if inner is None:
            raise FrontendError(
                f"call primitive {eqn.primitive.name!r} without inner jaxpr")
        ijaxpr = inner.jaxpr if hasattr(inner, "jaxpr") else inner
        sub: dict = {}
        for cv in ijaxpr.constvars:
            sub[cv] = _IMM
        for iv, ov in zip(ijaxpr.invars, eqn.invars):
            sub[iv] = self._resolve(ov, valmap)
        self.walk(ijaxpr, sub, vl)
        for outer, innerv in zip(eqn.outvars, ijaxpr.outvars):
            valmap[outer] = self._resolve(innerv, sub)

    # -- segments -----------------------------------------------------------
    def segment(self, seg):
        if isinstance(seg, ScalarWork):
            self._flush()
            self.ops.append({"op": "scalar", "count": seg.count, "fu": seg.fu,
                             "dep": seg.dep_scalar})
        elif isinstance(seg, RawRecords):
            self._flush()
            for rec in seg.records:
                self.ops.append({"op": "raw", "rec": dict(rec)})
        elif isinstance(seg, KernelBody):
            self._kernel_body(seg)
        else:
            raise FrontendError(f"unknown segment type {type(seg).__name__}")

    def _kernel_body(self, seg: KernelBody):
        vals = []
        for s in seg.ins:
            if isinstance(s, Stream):
                t = self.tok()
                self.stream_of_tok[t] = s
                op = {"op": "load", "vl": seg.vl, "stream": s, "out": t,
                      "idx": None}
                if seg.lazy_loads:
                    self._lazy[t] = op
                else:
                    self.emit(op)
                vals.append(_Val("vec", t))
            else:
                if s not in self.env:
                    raise FrontendError(f"segment input {s!r} not produced "
                                        "by an earlier segment")
                vals.append(_Val("vec", self.env[s]))
        avals = [jax.ShapeDtypeStruct((seg.vl,), jnp.float32) for _ in vals]
        closed = jax.make_jaxpr(seg.fn)(*avals)
        valmap = dict(zip(closed.jaxpr.invars, vals))
        for cv in closed.jaxpr.constvars:
            valmap[cv] = _IMM
        self.walk(closed.jaxpr, valmap, seg.vl)
        outvals = [self._resolve(v, valmap) for v in closed.jaxpr.outvars]
        # any block not yet fetched is still loaded (block-spec semantics)
        for t in list(self._lazy):
            self.emit(self._lazy.pop(t))
        if seg.outs and len(seg.outs) > len(outvals):
            raise FrontendError(
                f"{len(seg.outs)} outs declared, fn returned {len(outvals)}")
        for spec, val in zip(seg.outs, outvals):
            if spec is None:
                continue
            if isinstance(spec, Stream):
                if val.kind != "vec":
                    raise FrontendError(
                        f"store {spec.name!r} needs a vector value")
                elems = next((o.get("vl") for o in reversed(self.ops)
                              if o.get("out") == val.tok), seg.vl)
                self.emit({"op": "store", "vl": elems, "stream": spec,
                           "src": self.use(val)})
            else:
                if val.kind != "vec":
                    raise FrontendError(
                        f"named out {spec!r} needs a vector value")
                self.env[spec] = val.tok
        self._flush()


# --------------------------------------------------------------------------
# phase 2: live-range register allocation + record emission
# --------------------------------------------------------------------------

def _op_uses(op: dict) -> list[int]:
    if op["op"] == "arith":
        return list(op["srcs"])
    if op["op"] in ("slide", "reduce", "mask"):
        return [op["src"]] if op["src"] is not None else []
    if op["op"] == "load":
        return [op["idx"]] if op.get("idx") is not None else []
    if op["op"] == "store":
        return [op["src"]]
    return []


@dataclass
class Lowered:
    """A lowered chunk: the trace plus the allocator's pressure figures."""
    trace: isa.Trace
    max_live: int        # peak simultaneously-live logical registers
    regs_used: int       # distinct registers touched (cf. isa.trace_registers)


def _needs_idx_reg(op: dict) -> bool:
    """Does this vop carry an indexed stream access with no explicit index
    vector?  Real RVV spells these ``vluxei*``/``vsuxei*``, whose index
    vector is an architectural register source — the lowered trace reserves
    the top register for it so the decoded assembly round-trips bitwise."""
    if op["op"] == "load":
        return (op["stream"].pattern == isa.MEM_INDEXED
                and op.get("idx") is None)
    if op["op"] == "store":
        return op["stream"].pattern == isa.MEM_INDEXED
    return False


def lower(segments, n_regs: int = N_LOGICAL_REGS) -> Lowered:
    """Lower a kernel spec (list of segments) to a trace.

    Registers are assigned by live range: a linear scan over the vop list
    allocates the lowest free register at each definition and frees it after
    the value's last use; exceeding ``n_regs`` simultaneously-live values is
    a :class:`FrontendError` (the spec must spill explicitly, as canneal's
    ``RawRecords`` moves do).

    Indexed stream accesses (``MEM_INDEXED`` loads without an explicit
    gather index, and every indexed store) consume an implicit index vector:
    the allocator reserves the highest register (``n_regs - 1``) for it and
    records it as a source operand — exactly what ``vluxei64.v``/
    ``vsuxei64.v`` decode to, so the RVV round trip is bitwise.
    """
    w = _Walker()
    for seg in segments:
        w.segment(seg)
    w._flush()
    ops = w.ops

    last: dict[int, int] = {}
    for i, op in enumerate(ops):
        for t in _op_uses(op):
            last[t] = i

    idx_reg = n_regs - 1 if any(_needs_idx_reg(op) for op in ops) else -1
    free = [r for r in range(n_regs) if r != idx_reg]
    heapq.heapify(free)
    reg: dict[int, int] = {}
    max_live = 0
    used: set[int] = set()
    if idx_reg >= 0:
        used.add(idx_reg)
    b = isa.TraceBuilder()
    for i, op in enumerate(ops):
        sregs = []
        for t in _op_uses(op):
            if t not in reg:
                raise FrontendError("value used before definition")
            sregs.append(reg[t])
        for t in set(_op_uses(op)):
            if last[t] == i:
                heapq.heappush(free, reg.pop(t))
        dreg = -1
        t = op.get("out")
        if t is not None:
            if not free:
                raise FrontendError(
                    f"register pressure exceeds {n_regs} logical registers")
            dreg = heapq.heappop(free)
            reg[t] = dreg
            used.add(dreg)
            max_live = max(max_live, n_regs - len(free))
            if last.get(t, -1) <= i:        # dead value: reg recycles
                heapq.heappush(free, reg.pop(t))
        _emit_record(b, op, sregs, dreg, idx_reg)
    return Lowered(b.build(), max_live, len(used))


def _emit_record(b: isa.TraceBuilder, op: dict, sregs: list, dreg: int,
                 idx_reg: int = -1):
    kind = op["op"]
    if kind == "scalar":
        b.scalar(op["count"], fu=op["fu"], dep_scalar=op["dep"])
    elif kind == "raw":
        b.raw(op["rec"])
    elif kind == "load":
        s = op["stream"]
        rec = isa.vload(op["vl"], dst=dreg, pattern=s.pattern,
                        footprint_kb=s.footprint_kb)
        if sregs:                            # gather: consumes an index vector
            rec.update(n_src=1, src1=sregs[0])
        elif s.pattern == isa.MEM_INDEXED:   # implicit vluxei* index vector
            rec.update(n_src=1, src1=idx_reg)
        b.raw(rec)
    elif kind == "store":
        s = op["stream"]
        rec = isa.vstore(op["vl"], src1=sregs[0], pattern=s.pattern,
                         footprint_kb=s.footprint_kb)
        if s.pattern == isa.MEM_INDEXED:     # implicit vsuxei* index vector
            rec.update(n_src=2, src2=idx_reg)
        b.raw(rec)
    elif kind == "arith":
        b.arith(op["vl"], fu=op["fu"], n_src=op["n_src"],
                src1=sregs[0] if sregs else -1,
                src2=sregs[1] if len(sregs) > 1 else -1, dst=dreg)
    elif kind == "slide":
        b.slide(op["vl"], src1=sregs[0] if sregs else -1, dst=dreg)
    elif kind == "reduce":
        b.reduce(op["vl"], src1=sregs[0] if sregs else -1, dst=dreg,
                 fu=op["fu"])
    elif kind == "mask":
        b.mask_to_scalar(op["vl"], src1=sregs[0] if sregs else -1)
    else:  # pragma: no cover
        raise FrontendError(f"unknown vop {kind!r}")


def lower_trace(segments, n_regs: int = N_LOGICAL_REGS) -> isa.Trace:
    return lower(segments, n_regs=n_regs).trace


# --------------------------------------------------------------------------
# derived bodies + cross-validation against the hand-coded frontend
# --------------------------------------------------------------------------

_DERIVED_CACHE: dict = {}


def derived_body(app_name: str, mvl: int, cfg=None) -> Lowered:
    """Lower ``APPS[app_name].kernel(mvl, cfg)`` (cached, like body_for)."""
    from repro.core import tracegen
    key = (app_name, mvl, cfg)
    out = _DERIVED_CACHE.get(key)
    if out is None:
        spec = tracegen.APPS[app_name].kernel
        if spec is None:
            raise FrontendError(f"{app_name} has no kernel= spec")
        out = _DERIVED_CACHE[key] = lower(spec(mvl, cfg))
    return out


def trace_mix(trace: isa.Trace) -> dict:
    """FU-class fractions of a trace's VARITH instructions (an App.mix)."""
    fus = trace.fu[trace.kind == isa.VARITH]
    n = max(len(fus), 1)
    names = {_S: "simple", _M: "mul", _D: "div", _T: "trans"}
    return {names[c]: float(np.sum(fus == c)) / n for c in names}


# the shared contract (repro.core.crossval), re-exported for compatibility
CrossValReport = crossval.CrossValReport


def cross_validate_all(apps=None, cfgs=None) -> list[CrossValReport]:
    """Derived-vs-hand-coded contract for every app with both frontends;
    the timing comparison for every (app, cfg) pair runs as one batch."""
    from repro.core import engine as eng
    from repro.core import tracegen
    if apps is None:
        apps = list(tracegen.RIVEC_APPS)
    if cfgs is None:
        cfgs = [eng.VectorEngineConfig(mvl=64, lanes=4),
                eng.VectorEngineConfig(mvl=16, lanes=2)]

    def derive(app, eff, cfg):
        low = derived_body(app, eff, cfg)
        return low.trace, low.regs_used, low.max_live

    return crossval.cross_validate(derive, apps, cfgs)


def main(argv=None) -> int:
    ok = crossval.print_reports(cross_validate_all(),
                                "frontend cross-validation")
    return 0 if ok else 1


if __name__ == "__main__":
    # delegate to the canonical module object: specs built by tracegen carry
    # repro.core.frontend segment classes, not __main__ ones
    from repro.core import frontend as _canonical
    raise SystemExit(_canonical.main())
