"""Design-space exploration: many-config Pareto sweeps over the vector engine.

The paper's purpose is not the 24-point Table-10 grid — it is letting a
designer trade off MVL x lanes x memory hierarchy across usage scenarios
(§1, §5).  This module turns the batched engine into that tool:

* :class:`DesignSpace` — a declarative space over every live
  ``VectorEngineConfig`` knob (ranges/choices per field), enumerable to the
  full cartesian product or deterministically sampled.
* :func:`explore` — evaluates ``apps x configs`` through
  ``engine.steady_state_time_batch``.  The config axis is sharded across
  local devices by the engine's dispatch layer (``shard_map`` over a 1-D
  ``cfg`` mesh, single-device chunked fallback), and every dispatch is
  deduped through a persistent on-disk :class:`ResultCache` keyed by
  ``(trace fingerprint, config fingerprint, warmup/measure)`` — so a repeat
  sweep is pure cache lookups and two configs that induce the same clamped
  body + timing parameters are simulated once.
* :func:`pareto_frontier` / :func:`best_under_budget` — reductions over the
  records: per-app steady-state-runtime vs. area-proxy frontiers and
  "fastest config under an area budget" reports.

The area proxy (:func:`area_proxy_kb`) is a first-order silicon-cost model
in KB-of-SRAM equivalents: the VRF dominates a vector engine's area
(``phys_regs x mvl x 8B``, §3.2.2), each lane adds a datapath slice, and the
caches/queues contribute their capacity (the LLC discounted — it is shared
with the scalar core).  It is a *ranking* proxy for frontier shape, not a
layout estimate.

Determinism contract: same space + same apps -> byte-identical records and
frontiers, whether results come from simulation or from the cache (values
round-trip through JSON at full ``repr`` precision).  ``benchmarks/run.py
--dse`` asserts the repeat-run half of this; ``python -m repro.core.dse
--smoke`` is the CI gate.
"""
from __future__ import annotations

import itertools
import json
import os
import time
import warnings
from dataclasses import dataclass, fields

import numpy as np

try:
    import fcntl
except ImportError:          # non-POSIX: advisory locking degrades to none
    fcntl = None

from repro.core import engine as eng
from repro.core import isa, tracegen

_CFG_FIELDS = {f.name: f for f in fields(eng.VectorEngineConfig)}


# --------------------------------------------------------------------------
# the declarative space
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DesignSpace:
    """A declarative config space: ordered ``(field, choices)`` axes over
    ``VectorEngineConfig`` fields; every unlisted knob keeps its Table-10
    default.  Axis order fixes enumeration order (last axis fastest), which
    fixes record order, which makes whole sweeps reproducible byte-for-byte.

    >>> sp = DesignSpace.of("demo", mvl=(8, 64), lanes=(1, 4), mshrs=(1, 16))
    >>> sp.size()
    8
    >>> [ (c.mvl, c.lanes, c.mshrs) for c in sp.configs()[:3] ]
    [(8, 1, 1), (8, 1, 16), (8, 4, 1)]
    """
    name: str
    axes: tuple  # ((field_name, (choice, ...)), ...)

    def __post_init__(self):
        for name, choices in self.axes:
            if name not in _CFG_FIELDS:
                raise ValueError(f"unknown VectorEngineConfig field {name!r}")
            if not choices:
                raise ValueError(f"axis {name!r} has no choices")

    @staticmethod
    def of(name: str, **axes) -> "DesignSpace":
        return DesignSpace(name, tuple((k, tuple(v))
                                       for k, v in axes.items()))

    def size(self) -> int:
        n = 1
        for _, choices in self.axes:
            n *= len(choices)
        return n

    def config_at(self, index: int) -> eng.VectorEngineConfig:
        """Decode a flat index (mixed radix, last axis fastest) to a config."""
        if not 0 <= index < self.size():
            raise IndexError(index)
        kv = {}
        for name, choices in reversed(self.axes):
            index, r = divmod(index, len(choices))
            kv[name] = choices[r]
        return eng.VectorEngineConfig(**kv)

    def configs(self) -> list:
        """The full cartesian product, enumeration order."""
        names = [n for n, _ in self.axes]
        return [eng.VectorEngineConfig(**dict(zip(names, combo)))
                for combo in itertools.product(
                    *(choices for _, choices in self.axes))]

    def sample(self, n: int, seed: int = 0) -> list:
        """``n`` distinct configs, deterministic in ``seed`` (sorted flat
        indices, so the sample preserves enumeration order).

        ``n`` must not exceed ``size()``: the space cannot yield more
        distinct configs than it has, and silently returning fewer (or
        duplicating) would let a caller believe it explored ``n`` points.
        ``n == size()`` returns the full enumeration.

        >>> sp = DesignSpace.of("demo", mvl=(8, 64), lanes=(1, 4))
        >>> sp.sample(4) == sp.configs()
        True
        >>> sp.sample(5)
        Traceback (most recent call last):
            ...
        ValueError: sample(5) from 'demo' with only 4 configs
        """
        total = self.size()
        if n > total:
            raise ValueError(
                f"sample({n}) from {self.name!r} with only {total} configs")
        if n == total:
            return self.configs()
        idx = np.sort(np.random.RandomState(seed).choice(
            total, size=n, replace=False))
        return [self.config_at(int(i)) for i in idx]


# --------------------------------------------------------------------------
# the area/cost proxy
# --------------------------------------------------------------------------

# Per-lane datapath slice (ALU + FPU pipe + lane slice of the interconnect)
# in KB-of-SRAM equivalents; queue/ROB/MSHR entries are a fraction of a KB.
LANE_AREA_KB = 4.0
ENTRY_AREA_KB = 1.0 / 32.0
L2_SHARED_FRACTION = 1.0 / 8.0   # the LLC is shared with the scalar core


def area_proxy_kb(cfg: eng.VectorEngineConfig) -> float:
    """First-order area/cost proxy (KB-of-SRAM equivalents).

    VRF = ``phys_regs x mvl x 8 B`` — the §3.2.2 scaling argument: MVL and
    renaming depth buy capability linearly in register-file silicon.  Lanes
    buy datapath slices, L1 is private, the LLC is charged at its shared
    fraction, and queue/ROB/MSHR entries are bookkeeping SRAM.

    >>> small = area_proxy_kb(eng.VectorEngineConfig(mvl=8, lanes=1))
    >>> big = area_proxy_kb(eng.VectorEngineConfig(mvl=256, lanes=8))
    >>> small < big
    True
    """
    vrf_kb = cfg.phys_regs * cfg.mvl * 8.0 / 1024.0
    return float(
        vrf_kb
        + LANE_AREA_KB * cfg.lanes
        + cfg.l1_kb
        + L2_SHARED_FRACTION * cfg.l2_kb
        + ENTRY_AREA_KB * (cfg.rob_entries + 2 * cfg.queue_entries
                           + cfg.mshrs))


# --------------------------------------------------------------------------
# the persistent result cache
# --------------------------------------------------------------------------

class ResultCache:
    """Persistent on-disk memo of steady-state times, JSONL append-only.

    Key: ``{model_fp}|{trace_fp}|{config_fp}|w{warmup}m{measure}`` — the
    timing-model calibration hash (``engine.model_fingerprint``: a
    recalibration goes cold instead of serving stale timings), the trace
    content hash (``isa.trace_fingerprint``) and the timing-parameter hash
    (``engine.config_fingerprint``), so a hit can never cross workloads,
    calibrations or timing-relevant knobs, while configs aliasing to the
    same body + params (e.g. MVL above an app's ``max_vl`` cap) dedup to
    one dispatch.

    Values are floats serialized by ``json`` at full precision, so a cached
    sweep reproduces the simulated one byte-for-byte.  ``path=None`` gives a
    process-local (in-memory) cache.

    Robustness (the serve layer's crash-safety contract):

    * loading tolerates malformed lines — a process killed mid-append leaves
      at most one truncated trailing record, which is skipped with a warning
      (``corrupt_lines`` counts them) instead of poisoning the whole cache;
    * ``flush`` writes all pending records as ONE ``O_APPEND`` write under an
      advisory ``flock``, so concurrent writers (two ``--dse`` runs, or the
      simulation service and a sweep) never interleave partial lines.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self._mem: dict[str, float] = {}
        self._pending: list[tuple[str, float]] = []
        self.hits = 0
        self.misses = 0
        self.corrupt_lines = 0
        if path and os.path.exists(path):
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                        self._mem[rec["k"]] = float(rec["v"])
                    except (json.JSONDecodeError, KeyError, TypeError,
                            ValueError):
                        self.corrupt_lines += 1
                        warnings.warn(
                            f"ResultCache: skipping malformed line {lineno} "
                            f"of {path} (truncated write?)", stacklevel=2)

    def __len__(self) -> int:
        return len(self._mem)

    @staticmethod
    def key(body: isa.Trace, cfg: eng.VectorEngineConfig,
            warmup: int, measure: int) -> str:
        return (f"{eng.model_fingerprint()}|{isa.trace_fingerprint(body)}|"
                f"{eng.config_fingerprint(cfg)}|w{warmup}m{measure}")

    def get(self, key: str):
        v = self._mem.get(key)
        if v is None:
            self.misses += 1
        else:
            self.hits += 1
        return v

    def put(self, key: str, value: float) -> None:
        if key not in self._mem:
            self._mem[key] = float(value)
            self._pending.append((key, float(value)))

    def flush(self) -> None:
        """Append new entries to disk (no-op for in-memory caches).

        All pending records are buffered into one payload and appended with a
        single ``write`` on an ``O_APPEND`` descriptor under an exclusive
        advisory ``flock``: concurrent flushers serialize whole-payload, so
        the JSONL can never interleave partial lines, and a crash mid-write
        leaves at most one truncated trailing line (which ``__init__``
        skips).
        """
        if self.path and self._pending:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            payload = "".join(json.dumps({"k": k, "v": v}) + "\n"
                              for k, v in self._pending).encode()
            fd = os.open(self.path,
                         os.O_RDWR | os.O_CREAT | os.O_APPEND, 0o644)
            try:
                if fcntl is not None:
                    fcntl.flock(fd, fcntl.LOCK_EX)
                # a crashed writer may have left an unterminated trailing
                # line; terminate it so the new records don't merge into it
                size = os.fstat(fd).st_size
                if size and os.pread(fd, 1, size - 1) != b"\n":
                    payload = b"\n" + payload
                os.write(fd, payload)
            finally:
                if fcntl is not None:
                    fcntl.flock(fd, fcntl.LOCK_UN)
                os.close(fd)
        self._pending.clear()

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def records(self):
        """Iterate the cached ``(key, steady_ns)`` pairs, insertion order
        (disk order first, then in-run puts).  A pure read: unlike
        :meth:`get` it does not count toward hit/miss statistics — it is
        the offline-consumer view of the cache (training-data mining,
        audits), not the dispatch-dedup path."""
        yield from self._mem.items()

    def export_training_rows(self, apps, configs, warmup: int = 8,
                             measure: int = 24) -> list:
        """Join cached steady-state times back to explicit (app, config)
        cells — WITHOUT re-simulating anything.

        The JSONL values are keyed by opaque fingerprints, so an offline
        consumer (the surrogate cost model, ``repro.core.surrogate``) cannot
        reconstruct features from the cache alone; but given a candidate
        universe of apps x configs it can recompute every cell's key
        (``cell_key`` builds the body and fingerprints — no engine dispatch)
        and look the value up.  Cells absent from the cache are skipped.

        Returns one dict per labeled cell::

            {"app", "label", "cfg", "key", "steady_ns",   # the cached value
             "runtime_ns", "speedup", "area_kb"}          # derived, exact

        The derived quantities use the same arithmetic as :func:`explore`
        (``suite.vector_runtime_from_per_chunk``), so a row's ``runtime_ns``
        is bitwise-equal to the ``DseRecord`` the exploration produced.
        """
        from repro.core import suite
        cfgs = (configs.configs() if isinstance(configs, DesignSpace)
                else list(configs))
        model_fp = eng.model_fingerprint()
        rows = []
        for app in apps:
            for cfg in cfgs:
                body, key = cell_key(app, cfg, warmup, measure,
                                     model_fp=model_fp)
                v = self._mem.get(key)   # pure read: no hit/miss accounting
                if v is None:
                    continue
                runtime = suite.vector_runtime_from_per_chunk(app, cfg, body,
                                                              v)
                rows.append({
                    "app": app, "label": cfg.label(), "cfg": cfg, "key": key,
                    "steady_ns": v, "runtime_ns": runtime,
                    "speedup": suite.scalar_runtime_ns(app, cfg) / runtime,
                    "area_kb": area_proxy_kb(cfg),
                })
        return rows


# --------------------------------------------------------------------------
# cell keying — the contract shared by explore() and the serve layer
# --------------------------------------------------------------------------

# Every body/kernel consumes cfg only through cfg.mvl (the clamp), so bodies
# and their fingerprints memoize on (app, eff_mvl, cfg.mvl) — a SPACE_FULL
# sweep (or a long-lived service) builds ~tens of distinct bodies, not one
# per cell.  Config fingerprints memoize on the frozen config itself.
_BODY_FPS: dict[tuple, tuple] = {}
_CFG_FPS: dict = {}


def cell_body(app: str, cfg: eng.VectorEngineConfig) -> tuple:
    """Memoized ``(body, trace_fingerprint)`` for one (app, config) cell."""
    from repro.core import suite
    eff = suite.effective_mvl(app, cfg)
    bkey = (app, eff, cfg.mvl)
    ent = _BODY_FPS.get(bkey)
    if ent is None:
        body = tracegen.body_for(app, eff, cfg)
        ent = _BODY_FPS[bkey] = (body, isa.trace_fingerprint(body))
    return ent


def config_fp(cfg: eng.VectorEngineConfig) -> str:
    """Memoized ``engine.config_fingerprint`` (cfg is frozen/hashable)."""
    fp = _CFG_FPS.get(cfg)
    if fp is None:
        fp = _CFG_FPS[cfg] = eng.config_fingerprint(cfg)
    return fp


def cell_key(app: str, cfg: eng.VectorEngineConfig, warmup: int = 8,
             measure: int = 24, model_fp: str | None = None) -> tuple:
    """``(body, cache key)`` for one (app, config) cell — the single keying
    contract shared by :func:`explore` and ``repro.serve.sim_service``, so a
    service answer and a sweep answer for the same cell are the same cache
    entry.  ``model_fp`` may be passed to amortize ``model_fingerprint()``
    over a loop."""
    body, trace_fp = cell_body(app, cfg)
    mfp = model_fp if model_fp is not None else eng.model_fingerprint()
    return body, f"{mfp}|{trace_fp}|{config_fp(cfg)}|w{warmup}m{measure}"


# --------------------------------------------------------------------------
# exploration
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DseRecord:
    """One evaluated (app, config) cell."""
    app: str
    label: str
    cfg: eng.VectorEngineConfig
    steady_ns: float      # steady-state time of one loop body
    runtime_ns: float     # modeled whole-app vector runtime
    speedup: float        # vs. the scalar-pipeline model on cfg's scalar core
    area_kb: float        # area_proxy_kb(cfg)


@dataclass
class DseResult:
    """An exploration: the flat records plus dispatch/cache accounting."""
    space: str
    apps: tuple
    n_configs: int
    records: list         # [DseRecord], apps-major, enumeration order
    stats: dict           # lookups / simulated / hit_rate / ...

    def by_app(self) -> dict:
        out: dict[str, list] = {a: [] for a in self.apps}
        for r in self.records:
            out[r.app].append(r)
        return out

    def frontiers(self) -> dict:
        """Per-app Pareto frontier (minimize runtime_ns and area_kb)."""
        return {a: pareto_frontier(recs) for a, recs in self.by_app().items()}


def explore(space, apps=None, cache: ResultCache | None = None,
            warmup: int = 8, measure: int = 24) -> DseResult:
    """Evaluate every app on every config of ``space`` (a :class:`DesignSpace`
    or an explicit config list), going to the batched/sharded engine only
    for cache misses.

    The expensive quantity — the steady-state loop-body time — is cached per
    ``(body, timing params)``; the cheap derived quantities (whole-app
    runtime, speedup, area) are recomputed per record, so cached and
    simulated sweeps agree bitwise.
    """
    from repro.core import suite
    cfgs = space.configs() if isinstance(space, DesignSpace) else list(space)
    name = space.name if isinstance(space, DesignSpace) else f"list{len(cfgs)}"
    apps = tuple(sorted(tracegen.APPS)) if apps is None else tuple(apps)
    cache = cache if cache is not None else ResultCache()

    h0, m0 = cache.hits, cache.misses
    model_fp = eng.model_fingerprint()
    t_key0 = time.perf_counter()
    cells = []                       # (app, cfg, body, key)
    need: dict[str, tuple] = {}      # first (body, cfg) per missing key
    for app in apps:
        for cfg in cfgs:
            body, key = cell_key(app, cfg, warmup, measure,
                                 model_fp=model_fp)
            cells.append((app, cfg, body, key))
            if cache.get(key) is None and key not in need:
                need[key] = (body, cfg)
    t_key1 = t_disp1 = time.perf_counter()
    if need:
        times = eng.steady_state_time_batch(
            [b for b, _ in need.values()], [c for _, c in need.values()],
            warmup=warmup, measure=measure)
        for key, t in zip(need, times):
            cache.put(key, t)
        cache.flush()
        t_disp1 = time.perf_counter()

    records = []
    for app, cfg, body, key in cells:
        per_chunk = cache._mem[key]
        runtime = suite.vector_runtime_from_per_chunk(app, cfg, body,
                                                      per_chunk)
        records.append(DseRecord(
            app=app, label=cfg.label(), cfg=cfg, steady_ns=per_chunk,
            runtime_ns=runtime,
            speedup=suite.scalar_runtime_ns(app, cfg) / runtime,
            area_kb=area_proxy_kb(cfg)))
    t_derive1 = time.perf_counter()
    lookups = (cache.hits - h0) + (cache.misses - m0)
    from repro.core import telemetry
    phases = [
        telemetry.snapshot_row("dse.phase", phase="key", wall_s=t_key1 - t_key0,
                               cells=len(cells), misses=len(need)),
        telemetry.snapshot_row("dse.phase", phase="dispatch",
                               wall_s=t_disp1 - t_key1, simulated=len(need)),
        telemetry.snapshot_row("dse.phase", phase="derive",
                               wall_s=t_derive1 - t_disp1,
                               records=len(records)),
    ]
    stats = {
        "lookups": lookups,
        "disk_or_prior_hits": cache.hits - h0,
        "in_run_dedup": (cache.misses - m0) - len(need),
        "simulated": len(need),
        "hit_rate": (lookups - len(need)) / lookups if lookups else 0.0,
        "devices": _device_count(),
        "phases": phases,
    }
    return DseResult(space=name, apps=apps, n_configs=len(cfgs),
                     records=records, stats=stats)


def _device_count() -> int:
    import jax
    return jax.local_device_count()


# --------------------------------------------------------------------------
# reductions: Pareto frontiers + budget reports
# --------------------------------------------------------------------------

def pareto_frontier(records) -> list:
    """Non-dominated subset, minimizing ``(runtime_ns, area_kb)``.

    Sorted by runtime ascending; ties and duplicates resolve by
    ``(runtime, area, label)`` so the frontier is a pure function of the
    record *values* — the acceptance criterion's bitwise-identical-frontier
    guarantee.
    """
    out = []
    best_area = float("inf")
    for r in sorted(records, key=lambda r: (r.runtime_ns, r.area_kb, r.label)):
        if r.area_kb < best_area:
            out.append(r)
            best_area = r.area_kb
    return out


def best_under_budget(records, budget_kb: float):
    """The fastest record whose area proxy fits the budget (None if none)."""
    ok = [r for r in records if r.area_kb <= budget_kb]
    return min(ok, key=lambda r: (r.runtime_ns, r.area_kb, r.label),
               default=None)


def frontier_summary(result: DseResult, budgets=(256.0, 512.0, 1024.0)) -> dict:
    """JSON-able digest: per-app frontier points + best-under-budget table
    (the ``BENCH_pr4.json`` payload)."""
    out = {}
    by_app = result.by_app()
    for app, frontier in result.frontiers().items():
        recs = by_app[app]
        out[app] = {
            "frontier": [{"label": r.label, "runtime_ns": r.runtime_ns,
                          "area_kb": r.area_kb, "speedup": r.speedup}
                         for r in frontier],
            "best_under_budget_kb": {
                f"{b:g}": (lambda r: r.label if r else None)(
                    best_under_budget(recs, b)) for b in budgets},
        }
    return out


# --------------------------------------------------------------------------
# CLI / smoke gate
# --------------------------------------------------------------------------

def _frontier_fingerprint(result: DseResult) -> str:
    """Hash of every frontier's exact float values (bitwise contract)."""
    import hashlib
    h = hashlib.sha1()
    frontiers = result.frontiers()
    for app in result.apps:
        for r in frontiers[app]:
            h.update(f"{app}|{r.label}|{r.runtime_ns!r}|{r.area_kb!r}"
                     .encode())
    return h.hexdigest()[:16]


def main(argv=None) -> int:
    import argparse
    import time
    from repro.configs import vector_engine as vcfg
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--space", default="smoke",
                    choices=("smoke", "quick", "full"))
    ap.add_argument("--apps", default=None,
                    help="comma-separated app subset (default: space preset)")
    ap.add_argument("--cache", default=None, help="JSONL cache path")
    ap.add_argument("--budget-kb", type=float, default=512.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: explore twice through the same cache; "
                         "the second pass must be 100%% hits with a "
                         "bitwise-identical frontier")
    args = ap.parse_args(argv)
    space = {"smoke": vcfg.SPACE_SMOKE, "quick": vcfg.SPACE_QUICK,
             "full": vcfg.SPACE_FULL}[args.space]
    apps = (tuple(args.apps.split(",")) if args.apps
            else vcfg.SPACE_PRESET_APPS[args.space])

    cache = ResultCache(args.cache)
    t0 = time.perf_counter()
    res = explore(space, apps, cache=cache)
    wall = time.perf_counter() - t0
    apps = res.apps
    print(f"space={space.name} ({res.n_configs} configs) x {len(apps)} apps "
          f"-> {len(res.records)} cells in {wall:.2f}s on "
          f"{res.stats['devices']} device(s); "
          f"simulated={res.stats['simulated']} "
          f"hit_rate={res.stats['hit_rate']:.1%}")
    for app, frontier in sorted(res.frontiers().items()):
        best = best_under_budget(res.by_app()[app], args.budget_kb)
        print(f"  {app:16s} frontier={len(frontier):3d} pts   "
              f"best<= {args.budget_kb:g}KB: "
              f"{best.label if best else '(none fits)'}")
    if not args.smoke:
        return 0

    fp1 = _frontier_fingerprint(res)
    t0 = time.perf_counter()
    # a fresh cache object re-reads the JSONL from disk (the persistence
    # claim); without a path the warm in-memory cache is the subject
    res2 = explore(space, apps,
                   cache=ResultCache(args.cache) if args.cache else cache)
    wall2 = time.perf_counter() - t0
    fp2 = _frontier_fingerprint(res2)
    ok = (res2.stats["hit_rate"] == 1.0 and res2.stats["simulated"] == 0
          and fp1 == fp2)
    print(f"repeat pass: {wall2:.2f}s hit_rate={res2.stats['hit_rate']:.1%} "
          f"frontier {'bitwise-identical' if fp1 == fp2 else 'DIVERGED'} "
          f"-> {'ok' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    # delegate to the canonical module object: the spaces in repro.configs
    # carry repro.core.dse.DesignSpace instances, not __main__ ones
    from repro.core import dse as _canonical
    raise SystemExit(_canonical.main())
