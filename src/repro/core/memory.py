"""Analytic memory-hierarchy model: caches, MSHRs, DRAM bandwidth (§3.2.5).

Instead of per-app hand-coded miss constants, every vector memory access
derives its L1/L2 miss probabilities from three things it actually depends on:

  * the **footprint** of the stream it belongs to (the working set, in KB,
    between successive reuses of the same data — a per-record trace field),
  * the **access pattern** (unit / strided / indexed),
  * the **cache geometry** (``l1_kb``, ``l2_kb``, ``cache_line_bits``).

The steady-state residency model is the classic capacity argument (gem5's
classic memory system makes the same first-order approximation): a stream
whose footprint ``F`` is re-traversed through a cache of capacity ``C`` keeps
``min(1, C/F)`` of its lines resident, so the per-line miss probability is
``1 - min(1, C/F)``.  The L2 probability is conditional on missing L1
(inclusive hierarchy): ``P(L2 miss | L1 miss) = (1 - r2) / (1 - r1)``.

Service time splits into a **lead-in** (the exposed latency of the first
misses, before the pipeline fills) and a **throughput** term per access, the
max of three rates:

  * L1/port issue: one access per ``mem_ports`` per cycle,
  * L2 miss service: ``lat_l2 / overlap`` outstanding-miss concurrency,
  * DRAM: the larger of the MSHR-limited latency rate ``lat_dram / overlap``
    and the **bandwidth** cost of moving a full line, ``cache_line_bits / 8 /
    DRAM_BW_BYTES_PER_CYCLE``.  DRAM bandwidth is shared — it does *not*
    scale with ``mem_ports``.

``overlap`` is where the ``mshrs`` knob lives.  Regular streams (unit,
strided) are covered by the decoupled engine's run-ahead address generation
(§3.1): a stream-prefetch window of ``PREFETCH_DEPTH`` lines that does not
consume demand MSHRs (stream buffers in the Jouppi 1990 sense), so their
latency is hidden regardless of the MSHR file.  Indexed (gather) accesses are
demand misses: their concurrency is ``min(mshrs, DRAM_MLP)``, so ``mshrs=1``
fully serializes the random-walk apps (canneal) while leaving unit-stride
apps untouched.

Everything here is a pure function of traced scalars, so the engine's scan
step stays vmappable over the config axis.

>>> m1, m2 = miss_probs(13824.0, 32.0, 256.0)   # 13.5 MB stream, 32K/256K
>>> round(float(m1), 3), round(float(m2), 3)
(0.998, 0.984)
>>> m1, m2 = miss_probs(16.0, 32.0, 256.0)      # fits in L1
>>> float(m1), float(m2)
(0.0, 0.0)
>>> m2_small = miss_probs(768.0, 32.0, 256.0)[1]
>>> m2_big = miss_probs(768.0, 32.0, 1024.0)[1]
>>> float(m2_big) < float(m2_small)             # bigger LLC, fewer DRAM trips
True
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import isa

# Effective DRAM stream bandwidth, bytes per vector-engine cycle (1 GHz).
# 4 B/cycle = 4 GB/s sustained — DDR3-class effective (not peak) bandwidth,
# the paper's evaluation-era memory system.  A 512-bit line costs 16 cycles.
DRAM_BW_BYTES_PER_CYCLE = 4.0

# Bank-level parallelism cap: demand misses cannot overlap more than this in
# DRAM even with a larger MSHR file.
DRAM_MLP = 8.0

# Run-ahead depth of the decoupled VMU's stream prefetcher (lines).  Regular
# (unit/strided) streams are serviced from this window without consuming
# demand MSHRs, so `mshrs` does not gate them.
PREFETCH_DEPTH = 16.0


def residency(footprint_kb, cache_kb):
    """Steady-state fraction of a stream's lines resident in a cache.

    >>> float(residency(16.0, 32.0))
    1.0
    >>> float(residency(64.0, 32.0))
    0.5
    """
    return jnp.minimum(1.0, cache_kb / jnp.maximum(footprint_kb, 1e-6))


def miss_probs(footprint_kb, l1_kb, l2_kb):
    """Per-line (m1, m2): P(L1 miss) and P(L2 miss | L1 miss).

    Inclusive hierarchy: of the lines not resident in L1, the fraction also
    absent from L2 is ``(1 - r2) / (1 - r1)``.  Zero-footprint entries (NOPs,
    non-memory instructions) come out as (0, 0).
    """
    r1 = residency(footprint_kb, l1_kb)
    r2 = residency(footprint_kb, l2_kb)
    m1 = 1.0 - r1
    m2 = jnp.clip((1.0 - r2) / jnp.maximum(m1, 1e-6), 0.0, 1.0)
    return m1, m2


def overlap(pattern, mshrs):
    """Outstanding-miss concurrency available to one vector memory access.

    Indexed gathers are demand misses gated by the MSHR file (capped by DRAM
    bank parallelism); regular streams ride the run-ahead prefetch window.

    >>> float(overlap(isa.MEM_INDEXED, 16.0))
    8.0
    >>> float(overlap(isa.MEM_INDEXED, 1.0))
    1.0
    >>> float(overlap(isa.MEM_UNIT, 1.0))      # prefetched: MSHR-independent
    16.0
    """
    return jnp.where(jnp.asarray(pattern) == isa.MEM_INDEXED,
                     jnp.minimum(mshrs, DRAM_MLP), PREFETCH_DEPTH)


def dram_line_cycles(cache_line_bits, bw_bytes_cycle=DRAM_BW_BYTES_PER_CYCLE):
    """Bandwidth cost of moving one cache line from DRAM (cycles).

    >>> float(dram_line_cycles(512.0))
    16.0
    """
    return cache_line_bits / 8.0 / bw_bytes_cycle


def lead_cycles(m1, m2, lat_l1, lat_l2, lat_dram, ovl):
    """Exposed lead-in latency of a vector memory instruction: the expected
    miss path of the first accesses, amortized over the miss concurrency."""
    return lat_l1 + (m1 * lat_l2 + m1 * m2 * lat_dram) / ovl


def cycles_per_access(m1, m2, lat_l2, lat_dram, ovl, line_cyc, mem_ports):
    """Steady-state throughput cost of one access (one line for unit stride,
    one element for strided/indexed): max of the port rate, the MSHR-limited
    L2 and DRAM service rates, and the shared DRAM bandwidth.

    With 16 cycles/line DRAM bandwidth and full overlap, a pure DRAM stream
    costs 16 cycles per line; with ``ovl=1`` the same stream pays the full
    DRAM latency per miss:

    >>> float(cycles_per_access(1.0, 1.0, 12.0, 100.0, 8.0, 16.0, 1.0))
    16.0
    >>> float(cycles_per_access(1.0, 1.0, 12.0, 100.0, 1.0, 16.0, 1.0))
    100.0
    """
    port = 1.0 / mem_ports
    l2 = m1 * lat_l2 / ovl
    dram = m1 * m2 * jnp.maximum(lat_dram / ovl, line_cyc)
    return jnp.maximum(port, jnp.maximum(l2, dram))


def vector_access_cycles(vlf, pattern, footprint_kb, line_elems, l1_kb, l2_kb,
                         mshrs, lat_l1, lat_l2, lat_dram, line_cyc, mem_ports):
    """Total VMU occupancy (cycles) of one vector memory instruction.

    Unit-stride accesses are line-granular (``ceil(vl / line_elems)``
    accesses); strided and indexed accesses touch one line per element.
    All arguments may be traced scalars — this is called inside the engine's
    vmapped scan step.
    """
    m1, m2 = miss_probs(footprint_kb, l1_kb, l2_kb)
    ovl = overlap(pattern, mshrs)
    lead = lead_cycles(m1, m2, lat_l1, lat_l2, lat_dram, ovl)
    per = cycles_per_access(m1, m2, lat_l2, lat_dram, ovl, line_cyc, mem_ports)
    n_acc = jnp.where(jnp.asarray(pattern) == isa.MEM_UNIT,
                      jnp.ceil(vlf / line_elems), vlf)
    return lead + n_acc * per
