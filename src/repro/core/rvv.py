"""RVV v1.0 assembly frontend: decode RISC-V Vector streams into vector IR.

The suite's third trace source, next to the hand-coded ``tracegen`` bodies
and the jaxpr frontend (``repro.core.frontend``): a parser/decoder for RVV
v1.0 assembly text (GNU ``as`` syntax, as emitted by ``gcc -S`` or written
by hand) that lowers an instruction stream to an ``isa.Trace`` — the layer
that lets the simulator consume kernels the way the RiVec suite itself
ships, as RVV assembly.

The decoder is a small *abstract interpreter* over the instruction stream:

* **``vsetvli``/``vsetivli`` are executed**, not pattern-matched:
  ``VL = min(AVL, VLMAX)`` with ``VLMAX = VLEN/SEW * LMUL`` (``VLEN`` is the
  configured ``mvl`` in 64-bit elements), so the same ``.s`` file decodes
  to the right vector lengths at any hardware MVL.
* **Scalar registers carry abstract values** (known constants, ``la``
  symbols, or unknown).  Instructions that produce a *known* value —
  ``li``/``la``, induction updates, pointer bumps, trip counters — are
  loop/address bookkeeping: the abstract machine folds them away, because
  the characterized per-chunk scalar blocks in a kernel carry that overhead
  explicitly (as ``.rept`` filler on registers the machine cannot track).
  Scalar instructions over *unknown* values are the modeled scalar work:
  consecutive ones coalesce into ``SCALAR_BLOCK`` entries, and a block that
  reads a register written by ``vcpop.m``/``vfirst.m``/``vfmv.f.s`` (a
  vector-engine scalar result) is marked ``dep_scalar`` — the §4.1.4 stall.
* **Branches on known values are executed**, which is what expands a
  strip-mine loop: ``vsetvli t0, a0 … sub a0, a0, t0; bgtz a0, loop`` runs
  once per chunk with the exact per-iteration VL.  A loop whose head is
  marked with the ``.chunk`` directive is recognized as the kernel's
  steady-state chunk loop: its body is emitted once and the trip count
  (``ceil(AVL/VL)`` for strip-mine, the counter value for counted loops) is
  returned as the app's fractional chunk count instead of expanding
  millions of iterations.
* **Register usage is validated** against the 32-register file with LMUL
  register-group aliasing (a group's base must be LMUL-aligned and the
  whole group in range; reads require every physical register of the group
  to have been written).  ``isa.validate_trace`` re-checks the emitted
  trace independently (the fuzz tier in ``tests/test_rvv.py`` gates it).

Instruction-family → IR mapping (``docs/architecture.md`` has the table):

====================================  =====================================
RVV assembly                          vector IR
====================================  =====================================
``vle{8,16,32,64}.v`` / ``vse*.v``    ``VLOAD``/``VSTORE`` @ ``MEM_UNIT``
``vlse*.v`` / ``vsse*.v``             ``MEM_STRIDED``
``vluxei*/vloxei*/vsuxei*/vsoxei*``   ``MEM_INDEXED`` (index vector is a
                                      register source)
``vadd/vsub/vmin/vmax/vmseq/…``       ``VARITH`` @ ``FU_SIMPLE``
``vmul/vfmul/vfmacc/vmacc/…``         ``VARITH`` @ ``FU_MUL``
``vdiv/vfdiv/vfsqrt/vfrec7/…``        ``VARITH`` @ ``FU_DIV``
``vfexp/vflog/vfpow/… .v(v)``         ``VARITH`` @ ``FU_TRANS`` (pseudo-
                                      calls: vendor vector-libm lowering)
``vredsum/vfredosum/vfredusum/…``     ``VREDUCE``
``vslide1up/down``, ``vslideup/…``,   ``VSLIDE`` (lane interconnect)
``vrgather``, ``vcompress``
``vfirst.m`` / ``vcpop.m/vpopc.m``    ``VMASK_SCALAR`` (dest scalar reg
                                      becomes *hot*)
``vmv.v.*``, ``vmv<n>r.v``            ``VMOVE`` (whole-register moves run
                                      at ``n × VLEN/SEW`` elements
                                      regardless of VL — §4.1.2 spills)
``vmv.x.s`` / ``vfmv.f.s``            free transfer, dest scalar is hot
masking (trailing ``v0.t``)           one extra VRF read (``n_src += 1``)
scalar instructions                   coalesced ``SCALAR_BLOCK``
====================================  =====================================

Memory footprints come from ``.stream`` directives (``.stream name expr``,
where ``expr`` may reference ``vl``): a load/store whose address register
was ``la``-bound to a stream symbol carries that stream's working-set
footprint into the analytic memory model.  Approximations are documented
inline: the IR has two register-dependency slots, so FMAs keep the vector
multiplicand + accumulator; reductions keep the vector operand.

``asm_body``/``asm_chunks`` expose the per-app corpus
(``src/repro/asm/*.s``) as a trace source cross-validated against the
hand-coded bodies (``cross_validate_all``, the ``scripts/ci.sh``
``rvv-crossval`` gate: ``python -m repro.core.rvv --check-all``); ``python
-m repro.core.rvv kernel.s`` decodes and simulates an arbitrary kernel.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass

from repro.core import crossval, isa


class RvvError(Exception):
    """The stream uses a construct the decoder can't map (loud, like
    ``frontend.FrontendError``) or is ill-formed RVV."""


MAX_STEPS = 500_000   # abstract-interpreter fuel (per decode)

_S, _M, _D, _T = isa.FU_SIMPLE, isa.FU_MUL, isa.FU_DIV, isa.FU_TRANS

# --------------------------------------------------------------------------
# register names
# --------------------------------------------------------------------------

_X_ABI = ("zero ra sp gp tp t0 t1 t2 s0 s1 a0 a1 a2 a3 a4 a5 a6 a7 "
          "s2 s3 s4 s5 s6 s7 s8 s9 s10 s11 t3 t4 t5 t6").split()
_XREGS = {f"x{i}": i for i in range(32)}
_XREGS.update({n: i for i, n in enumerate(_X_ABI)})
_XREGS["fp"] = 8
_F_ABI = ("ft0 ft1 ft2 ft3 ft4 ft5 ft6 ft7 fs0 fs1 fa0 fa1 fa2 fa3 fa4 fa5 "
          "fa6 fa7 fs2 fs3 fs4 fs5 fs6 fs7 fs8 fs9 fs10 fs11 ft8 ft9 ft10 "
          "ft11").split()
_FREGS = {f"f{i}": i for i in range(32)}
_FREGS.update({n: i for i, n in enumerate(_F_ABI)})


def _xreg(tok: str):
    return _XREGS.get(tok)


def _freg(tok: str):
    return _FREGS.get(tok)


_VREG_RE = re.compile(r"^v([0-9]|[12][0-9]|3[01])$")


def _vreg(tok: str):
    m = _VREG_RE.match(tok)
    return int(m.group(1)) if m else None


def _imm(tok: str):
    try:
        return int(tok, 0)
    except ValueError:
        return None


_ADDR_RE = re.compile(r"^(-?\w*)\((\w+)\)$")

# --------------------------------------------------------------------------
# instruction classification tables
# --------------------------------------------------------------------------

VARITH_FU = {
    # simple: add/sub/logic/compare/min/max/merge/mask-logic
    "vadd": _S, "vsub": _S, "vrsub": _S, "vand": _S, "vor": _S, "vxor": _S,
    "vmin": _S, "vminu": _S, "vmax": _S, "vmaxu": _S, "vsll": _S,
    "vsrl": _S, "vsra": _S, "vmseq": _S, "vmsne": _S, "vmslt": _S,
    "vmsltu": _S, "vmsle": _S, "vmsleu": _S, "vmsgt": _S, "vmsgtu": _S,
    "vmsge": _S, "vmsgeu": _S, "vfadd": _S, "vfsub": _S, "vfrsub": _S,
    "vfmin": _S, "vfmax": _S, "vfabs": _S, "vfneg": _S, "vfsgnj": _S,
    "vfsgnjn": _S, "vfsgnjx": _S, "vmfeq": _S, "vmfne": _S, "vmflt": _S,
    "vmfle": _S, "vmfgt": _S, "vmfge": _S, "vmerge": _S, "vfmerge": _S,
    "vfclass": _S, "vid": _S, "viota": _S, "vmand": _S, "vmor": _S,
    "vmxor": _S, "vmnand": _S, "vmnor": _S, "vmxnor": _S, "vmandn": _S,
    "vmorn": _S, "vmnot": _S, "vmset": _S, "vmclr": _S, "vmmv": _S,
    # mul / fma
    "vmul": _M, "vmulh": _M, "vmulhu": _M, "vmulhsu": _M, "vfmul": _M,
    "vmacc": _M, "vnmsac": _M, "vmadd": _M, "vnmsub": _M, "vfmacc": _M,
    "vfnmacc": _M, "vfmsac": _M, "vfnmsac": _M, "vfmadd": _M,
    "vfnmadd": _M, "vfmsub": _M, "vfnmsub": _M,
    # div / sqrt
    "vdiv": _D, "vdivu": _D, "vrem": _D, "vremu": _D, "vfdiv": _D,
    "vfrdiv": _D, "vfsqrt": _D, "vfrsqrt7": _D, "vfrec7": _D,
    # transcendental pseudo-calls (vendor vector-libm lowering; RVV has no
    # hardware transcendentals — these stand for the intrinsic call sites)
    "vfexp": _T, "vflog": _T, "vfsin": _T, "vfcos": _T, "vftan": _T,
    "vfpow": _T, "vftanh": _T, "vferf": _T,
}

# FMA group: reads the accumulator vd in addition to its operands
FMA_MNEMOS = frozenset(
    "vmacc vnmsac vmadd vnmsub vfmacc vfnmacc vfmsac vfnmsac vfmadd "
    "vfnmadd vfmsub vfnmsub".split())

# mask-register operands are always a single v-register regardless of LMUL
# (RVV v1.0 §4.5/§15): comparisons write one, mask-logical ops read and
# write one, viota.m reads one
CMP_MNEMOS = frozenset(
    "vmseq vmsne vmslt vmsltu vmsle vmsleu vmsgt vmsgtu vmsge vmsgeu "
    "vmfeq vmfne vmflt vmfle vmfgt vmfge".split())
MASK_LOGICAL_MNEMOS = frozenset(
    "vmand vmor vmxor vmnand vmnor vmxnor vmandn vmorn vmnot vmset vmclr "
    "vmmv".split())

REDUCE_MNEMOS = frozenset(
    "vredsum vredmax vredmaxu vredmin vredminu vredand vredor vredxor "
    "vfredosum vfredusum vfredsum vfredmax vfredmin".split())

SLIDE_MNEMOS = frozenset(
    "vslideup vslidedown vslide1up vslide1down vfslide1up vfslide1down "
    "vrgather vrgatherei16 vcompress".split())

MASK_SCALAR_MNEMOS = frozenset(("vfirst", "vcpop", "vpopc"))

# vle64 / vse8: unit-stride; vlse/vsse: strided; vluxei/vloxei (+ store
# forms): indexed — exactly the three patterns the IR distinguishes
_MEM_RE = re.compile(r"^v([ls])(s|[uo]x)?ei?(8|16|32|64)$")
_MEM_PATTERN = {None: isa.MEM_UNIT, "s": isa.MEM_STRIDED,
                "ux": isa.MEM_INDEXED, "ox": isa.MEM_INDEXED}

# scalar mnemonics the abstract machine understands (3-operand ALU, 2-op
# immediates, moves, loads/stores, branches); anything else scalar-looking
# is rejected loudly
_SC_ALU3 = frozenset(
    "add sub mul mulh mulhu mulhsu mulw div divu rem remu and or xor sll "
    "srl sra slt sltu addw subw sllw srlw sraw sh1add sh2add sh3add min "
    "max minu maxu".split())
_SC_ALUI = frozenset(
    "addi andi ori xori slli srli srai slti sltiu addiw slliw srliw "
    "sraiw".split())
_SC_UNARY = frozenset("mv neg not seqz snez sltz sgtz sext.w zext.b "
                      "zext.h zext.w".split())
_SC_LOAD = frozenset("lb lh lw ld lbu lhu lwu".split())
_SC_STORE = frozenset("sb sh sw sd".split())
_SC_FLOAD = frozenset(("flw", "fld"))
_SC_FSTORE = frozenset(("fsw", "fsd"))
_BRANCH2 = frozenset("beq bne blt bge bltu bgeu bgt ble bgtu bleu".split())
_BRANCH1 = frozenset("beqz bnez blez bgez bltz bgtz".split())

# immediate/word ALU forms -> base op (for abstract evaluation)
_ALUI_BASE = {"addi": "add", "andi": "and", "ori": "or", "xori": "xor",
              "slli": "sll", "srli": "srl", "srai": "sra", "slti": "slt",
              "sltiu": "sltu", "addiw": "addw", "slliw": "sllw",
              "srliw": "srlw", "sraiw": "sraw"}

_SC_FU = {"mul": _M, "mulh": _M, "mulhu": _M, "mulhsu": _M, "mulw": _M,
          "div": _D, "divu": _D, "rem": _D, "remu": _D}
_F_FU = {"fmul": _M, "fmadd": _M, "fmsub": _M, "fnmadd": _M, "fnmsub": _M,
         "fdiv": _D, "fsqrt": _D}

# --------------------------------------------------------------------------
# parsing
# --------------------------------------------------------------------------


@dataclass
class _Stmt:
    mnemo: str
    ops: list
    line: int
    text: str


@dataclass
class _Program:
    stmts: list
    labels: dict            # name -> stmt index
    streams: dict           # name -> footprint expression (may use `vl`)
    chunk_ip: int | None    # stmt index the `.chunk` directive marks


def _safe_eval(expr: str, vl: int) -> float:
    """Evaluate a `.stream` footprint expression (numbers, `vl`, + - * / and
    parentheses only)."""
    def ev2(node):
        if isinstance(node, ast.Expression):
            return ev2(node.body)
        if isinstance(node, ast.Constant) and isinstance(node.value,
                                                         (int, float)):
            return node.value
        if isinstance(node, ast.Name) and node.id == "vl":
            return vl
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            return -ev2(node.operand)
        if isinstance(node, ast.BinOp):
            a, b = ev2(node.left), ev2(node.right)
            if isinstance(node.op, ast.Add):
                return a + b
            if isinstance(node.op, ast.Sub):
                return a - b
            if isinstance(node.op, ast.Mult):
                return a * b
            if isinstance(node.op, ast.Div):
                return a / b
        raise RvvError(f"unsupported term in stream expression {expr!r}")
    try:
        return float(ev2(ast.parse(expr, mode="eval")))
    except RvvError:
        raise
    except Exception as e:
        raise RvvError(f"bad stream expression {expr!r}: {e}") from None


def parse(text: str) -> _Program:
    """Assemble the text into statements, resolving labels, ``.stream``
    declarations, ``.rept``/``.endr`` expansion and the ``.chunk`` marker."""
    stmts: list[_Stmt] = []
    labels: dict[str, int] = {}
    streams: dict[str, str] = {}
    chunk_ip = None
    rept: list[tuple[int, list]] = []   # (count, collected raw lines) stack

    def add_line(raw: str, lineno: int):
        nonlocal chunk_ip
        line = raw.split("#", 1)[0].strip()
        if not line:
            return
        while True:                      # peel leading labels
            m = re.match(r"^([A-Za-z_.$][\w.$]*):\s*(.*)$", line)
            if not m:
                break
            labels[m.group(1)] = len(stmts)
            line = m.group(2).strip()
            if not line:
                return
        if line.startswith("."):
            parts = line.split()
            d = parts[0]
            if d == ".stream":
                if len(parts) < 3:
                    raise RvvError(f"line {lineno}: .stream needs "
                                   "<name> <footprint_kb expr>")
                streams[parts[1]] = "".join(parts[2:])
            elif d == ".chunk":
                if chunk_ip is not None:
                    raise RvvError(f"line {lineno}: duplicate .chunk")
                chunk_ip = len(stmts)
            elif d in (".rept", ".endr"):
                raise RvvError(f"line {lineno}: unbalanced {d}")
            # all other directives (.text/.globl/.align/...) are layout-only
            return
        mnemo, _, rest = line.partition(" ")
        ops = [o.strip() for o in rest.split(",")] if rest.strip() else []
        stmts.append(_Stmt(mnemo.strip(), ops, lineno, line))

    def feed(raw: str, lineno: int):
        stripped = raw.split("#", 1)[0].strip()
        if stripped.startswith(".rept"):
            n = _imm(stripped.split()[1]) if len(stripped.split()) > 1 else None
            if n is None or n < 0:
                raise RvvError(f"line {lineno}: bad .rept count")
            rept.append((n, []))
            return
        if stripped == ".endr":
            if not rept:
                raise RvvError(f"line {lineno}: .endr without .rept")
            n, body = rept.pop()
            for _ in range(n):
                for b_raw, b_no in body:
                    feed_expanded(b_raw, b_no)
            return
        if rept:
            rept[-1][1].append((raw, lineno))
            return
        add_line(raw, lineno)

    def feed_expanded(raw: str, lineno: int):
        # bodies of .rept may not define labels or nest further .rept
        stripped = raw.split("#", 1)[0].strip()
        if stripped.startswith(".rept") or stripped == ".endr" \
                or re.match(r"^[A-Za-z_.$][\w.$]*:", stripped):
            raise RvvError(f"line {lineno}: labels/.rept inside .rept body")
        add_line(raw, lineno)

    for i, raw in enumerate(text.splitlines(), start=1):
        feed(raw, i)
    if rept:
        raise RvvError(".rept without matching .endr")
    # a chunk marker at the very end of the file marks nothing
    if chunk_ip is not None and chunk_ip >= len(stmts):
        raise RvvError(".chunk marks no instruction")
    return _Program(stmts, labels, streams, chunk_ip)


# --------------------------------------------------------------------------
# the decoded result
# --------------------------------------------------------------------------

@dataclass
class Decoded:
    """One decoded kernel: the steady-state chunk body, its trip count, and
    the prologue (setup before the ``.chunk`` marker — register window
    initialization, stream binding; excluded from the body so chunk tiling
    matches the hand-coded bodies' steady-state semantics)."""
    trace: isa.Trace
    chunks: float
    prologue: isa.Trace
    vlmax: int
    whole_reg_elems: int
    prologue_defs: frozenset
    mnemonics: dict
    vl_cap: int = 0       # largest legal element count the stream could
                          # produce (VLMAX over executed vtypes, plus
                          # whole-register moves, which scale with 64/SEW)
    path: str = ""

    @property
    def full_trace(self) -> isa.Trace:
        return self.prologue.concat(self.trace)

    def validate(self, mvl: int | None = None) -> list[str]:
        """``isa.validate_trace`` over the body, with prologue defs live."""
        if mvl is None:
            mvl = max(self.vl_cap, self.vlmax, self.whole_reg_elems)
        return isa.validate_trace(self.trace, mvl,
                                  predefined=self.prologue_defs)


# --------------------------------------------------------------------------
# the abstract machine
# --------------------------------------------------------------------------

_UNKNOWN = None


class _Machine:
    def __init__(self, prog: _Program, vlmax: int, whole_reg: int,
                 expand: bool, avl: int | None):
        self.prog = prog
        self.vlen_bits = vlmax * 64          # hardware VLEN
        self.whole_reg = whole_reg           # elements per whole-reg move
        self.expand = expand
        self.x: list = [_UNKNOWN] * 32       # known ints / ('sym', s, off)
        self.x[0] = 0
        self.f: list = [_UNKNOWN] * 32
        self.hot_x: set[int] = set()
        self.hot_f: set[int] = set()
        self.vdef: set[int] = set()
        self.sew = 64
        self.lmul_num, self.lmul_den = 1, 1
        self.vl: int | None = None           # no vsetvli executed yet
        self.recs: list[dict] = []
        self._pend: dict | None = None
        self.mnemonics: dict[str, int] = {}
        self.chunks = 1.0
        self.vl_cap = 0
        self.prologue_len = 0
        self.prologue_defs: frozenset = frozenset()
        self.in_chunk = False
        self.chunk_done = False
        self.chunk_snap: list | None = None
        if avl is not None:
            self.x[_XREGS["a0"]] = int(avl)

    # ---- record emission ---------------------------------------------------
    def _flush(self):
        if self._pend is not None:
            self.recs.append(isa.scalar_block(self._pend["count"],
                                              fu=self._pend["fu"],
                                              dep_scalar=self._pend["dep"]))
            self._pend = None

    def emit_scalar(self, fu: int, dep: bool):
        if self._pend is not None and self._pend["fu"] != fu:
            self._flush()
        if self._pend is None:
            self._pend = {"count": 0, "fu": fu, "dep": False}
        self._pend["count"] += 1
        self._pend["dep"] |= dep

    def emit(self, rec: dict):
        self._flush()
        self.recs.append(rec)

    # ---- vector-register group bookkeeping ---------------------------------
    def _group(self, base: int, st: _Stmt, nregs: int | None = None) -> range:
        n = nregs if nregs is not None else max(self.lmul_num, 1)
        if self.lmul_den == 1 and n > 1 and base % n:
            raise RvvError(f"line {st.line}: v{base} is not aligned to the "
                           f"LMUL={n} register group ({st.text!r})")
        if base + n > 32:
            raise RvvError(f"line {st.line}: register group v{base}..v"
                           f"{base + n - 1} exceeds the 32-register file "
                           f"({st.text!r})")
        return range(base, base + n)

    def vread(self, base: int, st: _Stmt, nregs: int | None = None):
        for r in self._group(base, st, nregs):
            if r not in self.vdef:
                raise RvvError(f"line {st.line}: v{r} read before any write "
                               f"({st.text!r})")

    def vwrite(self, base: int, st: _Stmt, nregs: int | None = None):
        self.vdef.update(self._group(base, st, nregs))

    def need_vl(self, st: _Stmt) -> int:
        if self.vl is None:
            raise RvvError(f"line {st.line}: vector instruction before any "
                           f"vsetvli ({st.text!r})")
        return self.vl

    # ---- operand helpers ---------------------------------------------------
    def xval(self, tok: str, st: _Stmt):
        r = _xreg(tok)
        if r is None:
            raise RvvError(f"line {st.line}: expected scalar register, got "
                           f"{tok!r} ({st.text!r})")
        return self.x[r]

    def stream_of(self, addr_tok: str, st: _Stmt):
        """Footprint (KB) of the stream an address register is bound to."""
        m = _ADDR_RE.match(addr_tok)
        if not m or _xreg(m.group(2)) is None:
            raise RvvError(f"line {st.line}: expected address operand like "
                           f"(a0), got {addr_tok!r}")
        v = self.x[_xreg(m.group(2))]
        if isinstance(v, tuple) and v[0] == "sym" \
                and v[1] in self.prog.streams:
            return _safe_eval(self.prog.streams[v[1]], self.need_vl(st))
        return 64.0   # unbound address: the frontend's default footprint

    # ---- vsetvli ----------------------------------------------------------
    def _vtype(self, toks: list, st: _Stmt):
        for t in toks:
            t = t.strip()
            if re.match(r"^e(8|16|32|64)$", t):
                self.sew = int(t[1:])
            elif re.match(r"^m[1248]$", t):
                self.lmul_num, self.lmul_den = int(t[1:]), 1
            elif re.match(r"^mf[248]$", t):
                self.lmul_num, self.lmul_den = 1, int(t[2:])
            elif t in ("ta", "tu", "ma", "mu"):
                pass
            else:
                raise RvvError(f"line {st.line}: bad vtype token {t!r}")

    def vlmax(self) -> int:
        return max((self.vlen_bits // self.sew) * self.lmul_num
                   // self.lmul_den, 1)

    def do_vset(self, st: _Stmt):
        if st.mnemo == "vsetvl":
            raise RvvError(f"line {st.line}: vsetvl (vtype from register) "
                           "is not decodable; use vsetvli/vsetivli")
        if len(st.ops) < 3:
            raise RvvError(f"line {st.line}: {st.mnemo} needs rd, avl, vtype")
        rd = _xreg(st.ops[0])
        if rd is None:
            raise RvvError(f"line {st.line}: bad rd {st.ops[0]!r}")
        self._vtype(st.ops[2:], st)
        if st.mnemo == "vsetivli":
            avl = _imm(st.ops[1])
            if avl is None:
                raise RvvError(f"line {st.line}: vsetivli needs an "
                               "immediate AVL")
        else:
            rs1 = _xreg(st.ops[1])
            if rs1 is None:
                raise RvvError(f"line {st.line}: bad AVL register "
                               f"{st.ops[1]!r}")
            if rs1 == 0:
                # vsetvli rd, x0: VLMAX request (rd!=x0) / vtype-only change
                avl = self.vlmax() if rd != 0 else (self.vl or self.vlmax())
            else:
                avl = self.x[rs1]
                if not isinstance(avl, int):
                    raise RvvError(
                        f"line {st.line}: AVL register {st.ops[1]} has no "
                        "known value — initialize it (li) or pass --avl")
        self.vl = min(avl, self.vlmax())
        self.vl_cap = max(self.vl_cap, self.vlmax())
        if rd != 0:
            self.x[rd] = self.vl
            self.hot_x.discard(rd)

    # ---- vector instructions ----------------------------------------------
    def _mask_suffix(self, ops: list, st: _Stmt,
                     bare_v0: bool = False) -> tuple[list, int]:
        """Strip a trailing ``v0.t`` mask operand (one extra VRF read).
        ``bare_v0`` additionally strips a trailing bare ``v0`` — only the
        vmerge/vadc family spells its always-on mask that way."""
        last = ops[-1] if ops else ""
        if len(ops) > 1 and (last == "v0.t" or (bare_v0 and last == "v0")):
            self.vread(0, st, nregs=1)
            return ops[:-1], 1
        return ops, 0

    def do_vector(self, st: _Stmt) -> bool:
        """Decode one vector instruction; returns False if ``st`` is not a
        vector instruction."""
        mnemo = st.mnemo
        if "." not in mnemo:
            return False
        base, suffix = mnemo.split(".", 1)
        if not base.startswith("v"):
            return False
        vl = None

        # ---- memory -------------------------------------------------------
        m = _MEM_RE.match(base)
        if m and suffix == "v":
            vl = self.need_vl(st)
            is_load = m.group(1) == "l"
            pattern = _MEM_PATTERN[m.group(2)]
            ops, extra = self._mask_suffix(st.ops, st)
            if len(ops) < 2:
                raise RvvError(f"line {st.line}: {mnemo} needs vd, (rs1)")
            vd = _vreg(ops[0])
            if vd is None:
                raise RvvError(f"line {st.line}: bad vector register "
                               f"{ops[0]!r}")
            fp = self.stream_of(ops[1], st)
            idx = None
            if pattern == isa.MEM_INDEXED:
                if len(ops) < 3 or _vreg(ops[2]) is None:
                    raise RvvError(f"line {st.line}: {mnemo} needs an index "
                                   "vector operand")
                idx = _vreg(ops[2])
                self.vread(idx, st)
            elif pattern == isa.MEM_STRIDED:
                if len(ops) < 3 or _xreg(ops[2]) is None:
                    raise RvvError(f"line {st.line}: {mnemo} needs a stride "
                                   "register operand")
            if is_load:
                rec = isa.vload(vl, dst=vd, pattern=pattern, footprint_kb=fp)
                if idx is not None:
                    rec.update(n_src=1 + extra, src1=idx)
                elif extra:
                    rec.update(n_src=extra)
                self.vwrite(vd, st)
            else:
                self.vread(vd, st)
                rec = isa.vstore(vl, src1=vd, pattern=pattern,
                                 footprint_kb=fp)
                rec.update(n_src=1 + extra + (1 if idx is not None else 0))
                if idx is not None:
                    rec.update(src2=idx)
            self.emit(rec)
            return True

        # ---- vset ---------------------------------------------------------
        if base in ("vsetvli", "vsetivli", "vsetvl"):
            return False    # handled by the caller (no '.' in mnemonic)

        # ---- whole-register moves ----------------------------------------
        wm = re.match(r"^vmv([1248])r$", base)
        if wm and suffix == "v":
            n = int(wm.group(1))
            vd, vs = _vreg(st.ops[0]), _vreg(st.ops[1])
            if vd is None or vs is None:
                raise RvvError(f"line {st.line}: bad operands ({st.text!r})")
            if vd % n or vs % n:
                raise RvvError(f"line {st.line}: vmv{n}r.v registers must "
                               f"be {n}-aligned")
            self.vread(vs, st, nregs=n)
            self.vwrite(vd, st, nregs=n)
            # whole-register moves ignore VL: n x VLEN/SEW elements (the
            # §4.1.2 full-MVL spill cost)
            elems = n * (self.whole_reg * 64 // self.sew)
            self.vl_cap = max(self.vl_cap, elems)
            self.emit(isa.vmove(elems, src1=vs, dst=vd))
            return True

        # ---- vmv family ---------------------------------------------------
        if base in ("vmv", "vfmv"):
            vl = self.need_vl(st)
            if suffix in ("v.v",):
                vd, vs = _vreg(st.ops[0]), _vreg(st.ops[1])
                self.vread(vs, st)
                self.vwrite(vd, st)
                self.emit(isa.vmove(vl, src1=vs, dst=vd))
            elif suffix in ("v.x", "v.i", "v.f"):
                vd = _vreg(st.ops[0])
                self.vwrite(vd, st)
                rec = isa.vmove(vl, src1=-1, dst=vd)
                rec.update(n_src=0)
                self.emit(rec)
            elif suffix in ("s.x", "s.f"):
                vd = _vreg(st.ops[0])
                self.vwrite(vd, st, nregs=1)
                rec = isa.vmove(1, src1=-1, dst=vd)
                rec.update(n_src=0)
                self.emit(rec)
            elif suffix in ("x.s", "f.s"):
                # element extract to the scalar core: free transfer, but the
                # destination is hot (a dependent scalar block must wait)
                vs = _vreg(st.ops[1])
                self.vread(vs, st, nregs=1)
                if suffix == "x.s":
                    rd = _xreg(st.ops[0])
                    self.x[rd] = _UNKNOWN
                    self.hot_x.add(rd)
                else:
                    rd = _freg(st.ops[0])
                    self.f[rd] = _UNKNOWN
                    self.hot_f.add(rd)
            else:
                raise RvvError(f"line {st.line}: unsupported move "
                               f"{mnemo!r}")
            return True

        # ---- mask -> scalar (vfirst/vcpop) --------------------------------
        if base in MASK_SCALAR_MNEMOS and suffix == "m":
            vl = self.need_vl(st)
            rd, vs = _xreg(st.ops[0]), _vreg(st.ops[1])
            if rd is None or vs is None:
                raise RvvError(f"line {st.line}: {mnemo} needs rd, vs")
            self.vread(vs, st, nregs=1)
            self.emit(isa.vmask_scalar(vl, src1=vs))
            self.x[rd] = _UNKNOWN
            self.hot_x.add(rd)
            return True

        # ---- reductions ---------------------------------------------------
        if base in REDUCE_MNEMOS and suffix == "vs":
            vl = self.need_vl(st)
            ops, _ = self._mask_suffix(st.ops, st)
            vd, vs2, vs1 = (_vreg(ops[0]), _vreg(ops[1]),
                            _vreg(ops[2]) if len(ops) > 2 else None)
            if vd is None or vs2 is None:
                raise RvvError(f"line {st.line}: {mnemo} needs vd, vs2, vs1")
            self.vread(vs2, st)
            if vs1 is not None:
                self.vread(vs1, st, nregs=1)
            self.vwrite(vd, st, nregs=1)
            # IR reductions carry one register dependency: the vector
            # operand (the scalar seed vs1 is almost always loop-invariant)
            self.emit(isa.vreduce(vl, src1=vs2, dst=vd, fu=_S))
            return True

        # ---- slides / register gathers ------------------------------------
        if base in SLIDE_MNEMOS:
            vl = self.need_vl(st)
            ops, extra = self._mask_suffix(st.ops, st)
            vd, vs2 = _vreg(ops[0]), _vreg(ops[1])
            if vd is None or vs2 is None:
                raise RvvError(f"line {st.line}: {mnemo} needs vd, vs2")
            self.vread(vs2, st)
            rec = isa.vslide(vl, src1=vs2, dst=vd)
            vs1 = _vreg(ops[2]) if len(ops) > 2 else None
            if vs1 is not None:          # vrgather.vv / vcompress.vm index
                # vcompress's selector is a mask: one register at any LMUL
                self.vread(vs1, st,
                           nregs=1 if base == "vcompress" else None)
                rec.update(n_src=2 + extra, src2=vs1)
            elif extra:
                rec.update(n_src=1 + extra)
            self.vwrite(vd, st)
            self.emit(rec)
            return True

        # ---- arithmetic ---------------------------------------------------
        if base in VARITH_FU:
            vl = self.need_vl(st)
            fu = VARITH_FU[base]
            ops, extra = self._mask_suffix(
                st.ops, st, bare_v0=suffix in ("vvm", "vxm", "vim"))
            vd = _vreg(ops[0])
            if vd is None:
                raise RvvError(f"line {st.line}: bad destination "
                               f"{ops[0]!r} ({st.text!r})")
            # mask registers are single registers whatever the LMUL
            src_n = 1 if base in MASK_LOGICAL_MNEMOS \
                or base == "viota" else None
            dst_n = 1 if base in MASK_LOGICAL_MNEMOS \
                or base in CMP_MNEMOS else None
            vsrcs = [v for v in (_vreg(o) for o in ops[1:]) if v is not None]
            for v in vsrcs:
                self.vread(v, st, nregs=src_n)
            if base in FMA_MNEMOS:
                # vd is also read (accumulator).  The IR has two dependency
                # slots: keep the (last) vector operand and the accumulator.
                self.vread(vd, st)
                src1 = vsrcs[-1] if vsrcs else -1
                src2 = vd
                n_src = 1 + len(vsrcs) + extra
            else:
                src1 = vsrcs[0] if vsrcs else -1
                src2 = vsrcs[1] if len(vsrcs) > 1 else -1
                n_src = len(vsrcs) + extra
            self.vwrite(vd, st, nregs=dst_n)
            self.emit(isa.varith(vl, fu=fu, n_src=n_src, src1=src1,
                                 src2=src2, dst=vd))
            return True

        if base.startswith("v"):
            raise RvvError(f"line {st.line}: no vector-IR mapping for "
                           f"{mnemo!r} (see rvv.VARITH_FU and friends)")
        return False

    # ---- scalar instructions ----------------------------------------------
    def _sc_read(self, tok: str, st: _Stmt):
        """(value, hot) of a scalar operand (x-reg, f-reg or immediate)."""
        r = _xreg(tok)
        if r is not None:
            return self.x[r], r in self.hot_x
        fr = _freg(tok)
        if fr is not None:
            return self.f[fr], fr in self.hot_f
        v = _imm(tok)
        if v is not None:
            return v, False
        m = _ADDR_RE.match(tok)
        if m is not None and _xreg(m.group(2)) is not None:
            return _UNKNOWN, _xreg(m.group(2)) in self.hot_x
        # anything else (a typo'd register, a %lo() relocation, ...) must
        # not silently become a foldable symbol value
        raise RvvError(f"line {st.line}: unknown scalar operand {tok!r} "
                       f"({st.text!r})")

    def _sc_write(self, tok: str, value, hot: bool, st: _Stmt):
        r = _xreg(tok)
        if r is not None:
            if r != 0:
                self.x[r] = value
                (self.hot_x.add if hot else self.hot_x.discard)(r)
            return
        fr = _freg(tok)
        if fr is not None:
            self.f[fr] = value
            (self.hot_f.add if hot else self.hot_f.discard)(fr)
            return
        raise RvvError(f"line {st.line}: bad destination {tok!r} "
                       f"({st.text!r})")

    def do_scalar(self, st: _Stmt):
        """Abstract-interpret one scalar instruction.  Instructions whose
        result the machine can track (constants, symbols, induction
        arithmetic) are loop/address bookkeeping and fold away; the rest
        are the modeled scalar work and coalesce into SCALAR_BLOCKs."""
        m, ops = st.mnemo, st.ops
        val = _UNKNOWN
        base = _ALUI_BASE.get(m, m)

        def binop(a, b):
            if isinstance(a, int) and isinstance(b, int):
                return {"add": a + b, "sub": a - b, "mul": a * b,
                        "and": a & b, "or": a | b, "xor": a ^ b,
                        "sll": a << (b & 63), "srl": a >> (b & 63),
                        "sra": a >> (b & 63),
                        "sh1add": (a << 1) + b, "sh2add": (a << 2) + b,
                        "sh3add": (a << 3) + b,
                        "slt": int(a < b), "sltu": int(a < b),
                        "min": min(a, b), "max": max(a, b),
                        "minu": min(a, b), "maxu": max(a, b),
                        "addw": a + b, "subw": a - b, "mulw": a * b,
                        "sllw": a << (b & 31), "srlw": a >> (b & 31),
                        "sraw": a >> (b & 31),
                        }.get(base)
            if isinstance(a, tuple) and a[0] == "sym" and isinstance(b, int):
                if base in ("add", "addw"):
                    return ("sym", a[1], a[2] + b)
                if base in ("sub", "subw"):
                    return ("sym", a[1], a[2] - b)
            if isinstance(b, tuple) and b[0] == "sym" and isinstance(a, int) \
                    and base in ("add", "addw"):
                return ("sym", b[1], b[2] + a)
            return _UNKNOWN

        hot = False
        if m == "li":
            v = _imm(ops[1])
            if v is None:
                raise RvvError(f"line {st.line}: bad li immediate")
            self._sc_write(ops[0], v, False, st)
            return
        if m in ("la", "lla"):
            self._sc_write(ops[0], ("sym", ops[1], 0), False, st)
            return
        if m == "lui":
            v = _imm(ops[1])
            self._sc_write(ops[0], (v << 12) if v is not None else _UNKNOWN,
                           False, st)
            return
        if m == "nop":
            return
        if m in _SC_UNARY:
            a, hot = self._sc_read(ops[1], st)
            if m == "mv" or m.startswith(("sext", "zext")):
                val = a
            elif m == "neg" and isinstance(a, int):
                val = -a
            elif m == "not" and isinstance(a, int):
                val = ~a
            elif m in ("seqz", "snez", "sltz", "sgtz") and isinstance(a, int):
                val = int({"seqz": a == 0, "snez": a != 0,
                           "sltz": a < 0, "sgtz": a > 0}[m])
            self._sc_write(ops[0], val, hot and val is _UNKNOWN, st)
            if val is _UNKNOWN:
                self.emit_scalar(_S, hot)
            return
        if m in _SC_ALU3 or m in _SC_ALUI:
            a, h1 = self._sc_read(ops[1], st)
            b, h2 = self._sc_read(ops[2], st)
            val = binop(a, b)
            hot = h1 or h2
            self._sc_write(ops[0], val, hot and val is _UNKNOWN, st)
            if val is _UNKNOWN:
                self.emit_scalar(_SC_FU.get(m, _S), hot)
            return
        if m in _SC_LOAD or m in _SC_FLOAD:
            _, hot = self._sc_read(ops[1], st)
            self._sc_write(ops[0], _UNKNOWN, hot, st)
            self.emit_scalar(_S, hot)
            return
        if m in _SC_STORE or m in _SC_FSTORE:
            _, h1 = self._sc_read(ops[0], st)
            _, h2 = self._sc_read(ops[1], st)
            self.emit_scalar(_S, h1 or h2)
            return
        if m.startswith("f") and "." in m:
            fbase = m.split(".", 1)[0]
            hot = any(self._sc_read(o, st)[1] for o in ops[1:])
            self._sc_write(ops[0], _UNKNOWN, hot, st)
            self.emit_scalar(_F_FU.get(fbase, _S), hot)
            return
        if m.startswith("csr"):
            if ops:
                self._sc_write(ops[0], _UNKNOWN, False, st)
            self.emit_scalar(_S, False)
            return
        if m in ("call", "tail", "jalr"):
            raise RvvError(
                f"line {st.line}: external call {st.text!r} is not "
                "decodable — transcendental math must use the vf* "
                "pseudo-instructions (vfexp.v / vflog.v / vfpow.vv / ...)")
        raise RvvError(f"line {st.line}: unsupported mnemonic {m!r} "
                       f"({st.text!r})")


def _branch_taken(m: str, a, b, st: _Stmt) -> bool:
    for v in (a, b):
        if not isinstance(v, int):
            raise RvvError(
                f"line {st.line}: branch on unknown value ({st.text!r}) — "
                "the decoder executes control flow, so loop bounds must be "
                "known (li) or the loop marked .chunk")
    return {"beq": a == b, "bne": a != b, "blt": a < b, "bge": a >= b,
            "bltu": a < b, "bgeu": a >= b, "bgt": a > b, "ble": a <= b,
            "bgtu": a > b, "bleu": a <= b}[m]


# --------------------------------------------------------------------------
# the decode driver
# --------------------------------------------------------------------------

def decode(text: str, mvl: int = 256, cfg=None, *, expand: bool = False,
           avl: int | None = None, path: str = "<string>") -> Decoded:
    """Decode RVV assembly text to a :class:`Decoded` chunk.

    ``mvl`` is the hardware MVL in 64-bit elements (``VLEN = mvl*64`` bits);
    with ``cfg`` (a ``VectorEngineConfig``) the effective VLEN is
    ``min(mvl, cfg.mvl)`` and whole-register moves run at ``cfg.mvl``
    elements (the §4.1.2 semantics the hand-coded canneal body models).
    ``expand=True`` ignores any ``.chunk`` marker and concretely expands
    every loop (exact tail VLs) — the mode the strip-mine invariance test
    uses; the default emits the marked steady-state loop once and returns
    its trip count in ``chunks``.
    """
    prog = parse(text)
    vlmax = min(mvl, cfg.mvl) if cfg is not None else mvl
    whole = cfg.mvl if cfg is not None else mvl
    mach = _Machine(prog, vlmax, whole, expand, avl)
    chunk_ip = None if expand else prog.chunk_ip

    ip, fuel = 0, MAX_STEPS
    n = len(prog.stmts)
    while ip < n:
        if ip == chunk_ip and not mach.in_chunk and not mach.chunk_done:
            mach._flush()
            mach.in_chunk = True
            mach.prologue_len = len(mach.recs)
            mach.prologue_defs = frozenset(mach.vdef)
            mach.chunk_snap = list(mach.x)
        fuel -= 1
        if fuel <= 0:
            raise RvvError(
                f"{path}: decode exceeded {MAX_STEPS} steps — mark the "
                "steady-state loop with .chunk or reduce the AVL")
        st = prog.stmts[ip]
        m = st.mnemo
        mach.mnemonics[m] = mach.mnemonics.get(m, 0) + 1

        # control flow ------------------------------------------------------
        if m in ("ret", "ebreak", "unimp"):
            break
        if m == "jr" and st.ops and st.ops[0] == "ra":
            break
        if m in ("j", "jal"):
            tgt = st.ops[-1]
            if tgt not in prog.labels:
                raise RvvError(f"line {st.line}: unknown label {tgt!r}")
            ip = prog.labels[tgt]
            continue
        if m in _BRANCH1 or m in _BRANCH2:
            if m in _BRANCH1:
                base = "b" + m[1:-1]          # beqz -> beq vs zero
                a, _ = mach._sc_read(st.ops[0], st)
                b = 0
                tgt = st.ops[1]
                creg = _xreg(st.ops[0])
            else:
                base = m
                a, _ = mach._sc_read(st.ops[0], st)
                b, _ = mach._sc_read(st.ops[1], st)
                tgt = st.ops[2]
                creg = _xreg(st.ops[0])
            if tgt not in prog.labels:
                raise RvvError(f"line {st.line}: unknown label {tgt!r}")
            tgt_ip = prog.labels[tgt]
            if (mach.in_chunk and tgt_ip == chunk_ip):
                # the steady-state chunk loop closes here: emit one body,
                # derive the trip count from the counter's affine step
                mach._flush()
                c0 = mach.chunk_snap[creg] if creg is not None else None
                c1 = mach.x[creg] if creg is not None else None
                if not (isinstance(c0, int) and isinstance(c1, int)
                        and c0 > c1):
                    raise RvvError(
                        f"line {st.line}: cannot derive the chunk trip "
                        "count — the .chunk loop must close on a counter "
                        f"decremented by a known step ({st.text!r})")
                d = c0 - c1
                if m in ("bnez", "bne") and c0 % d:
                    raise RvvError(
                        f"line {st.line}: bnez-closed .chunk loop needs "
                        f"AVL divisible by the step (AVL={c0}, step={d}); "
                        "close with bgtz for strip-mine tails")
                mach.chunks = c0 / d
                mach.in_chunk = False
                mach.chunk_done = True
                mach.x[creg] = 0
                ip += 1
                continue
            taken = _branch_taken(base, a, b, st)
            ip = tgt_ip if taken else ip + 1
            continue

        # vsetvli -------------------------------------------------------------
        if m in ("vsetvli", "vsetivli", "vsetvl"):
            mach.do_vset(st)
            ip += 1
            continue

        # vector / scalar -----------------------------------------------------
        if not mach.do_vector(st):
            mach.do_scalar(st)
        ip += 1

    mach._flush()
    if mach.in_chunk:
        raise RvvError(f"{path}: .chunk loop never closed (no backward "
                       "branch to the marker)")
    body = isa.Trace.from_records(mach.recs[mach.prologue_len:])
    prologue = isa.Trace.from_records(mach.recs[:mach.prologue_len])
    return Decoded(trace=body, chunks=mach.chunks, prologue=prologue,
                   vlmax=vlmax, whole_reg_elems=whole,
                   prologue_defs=mach.prologue_defs,
                   mnemonics=mach.mnemonics, vl_cap=mach.vl_cap, path=path)


def decode_file(path: str, mvl: int = 256, cfg=None, **kw) -> Decoded:
    with open(path) as f:
        return decode(f.read(), mvl, cfg, path=path, **kw)


# --------------------------------------------------------------------------
# the RiVec assembly corpus as a trace source (suite `:asm` variant)
# --------------------------------------------------------------------------

ASM_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "asm")

_DECODE_CACHE: dict = {}


def decode_app(app_name: str, mvl: int, cfg=None) -> Decoded:
    """Decode ``src/repro/asm/<app>.s`` at (mvl, cfg), cached like
    ``tracegen.body_for``."""
    from repro.core import tracegen
    app = tracegen.app_for(app_name)
    if not app.asm:
        raise RvvError(f"{app.name} has no asm= corpus entry")
    vlmax = min(mvl, cfg.mvl) if cfg is not None else mvl
    whole = cfg.mvl if cfg is not None else mvl
    key = (app.name, vlmax, whole)
    out = _DECODE_CACHE.get(key)
    if out is None:
        path = os.path.join(ASM_DIR, app.asm)
        out = _DECODE_CACHE[key] = decode_file(path, mvl, cfg)
    return out


def asm_body(app_name: str, mvl: int, cfg=None) -> isa.Trace:
    """The decoded chunk body — the ``:asm`` analogue of ``body_for``."""
    return decode_app(app_name, mvl, cfg).trace


def asm_chunks(app_name: str, mvl: int, cfg=None) -> float:
    """Chunk count derived from the ``.s`` file's own AVL / loop counter
    (``ceil``-free fractional count, like ``App.chunks``)."""
    return decode_app(app_name, mvl, cfg).chunks


CHECK_MVLS = (8, 16, 32, 64, 128, 256)


def cross_validate_all(apps=None, cfgs=None) -> list:
    """Decoded-vs-hand-coded contract (repro.core.crossval) for every app
    with an ``asm=`` corpus entry, at every MVL of the paper grid."""
    from repro.core import engine as eng
    from repro.core import tracegen
    if apps is None:
        apps = [a for a in sorted(tracegen.APPS) if tracegen.APPS[a].asm]
    if cfgs is None:
        cfgs = [eng.VectorEngineConfig(mvl=m, lanes=4) for m in CHECK_MVLS]

    def derive(app, eff, cfg):
        d = decode_app(app, eff, cfg)
        regs = isa.trace_registers(d.trace)
        return d.trace, regs, regs

    return crossval.cross_validate(derive, apps, cfgs)


def check_all(verbose: bool = True) -> bool:
    """The ci.sh ``rvv-crossval`` gate: static mixes exact + steady-state
    time within tolerance at every MVL, plus decoder-derived chunk counts
    against the characterized closed forms and body validation."""
    from repro.core import engine as eng
    from repro.core import suite, tracegen
    reports = cross_validate_all()
    ok = crossval.print_reports(reports, "rvv cross-validation") \
        if verbose else all(r.ok for r in reports)
    for app in [a for a in sorted(tracegen.APPS) if tracegen.APPS[a].asm]:
        for m in CHECK_MVLS:
            cfg = eng.VectorEngineConfig(mvl=m, lanes=4)
            eff = suite.effective_mvl(app, cfg)
            d = decode_app(app, eff, cfg)
            want = tracegen.APPS[app].chunks(eff)
            rel = abs(d.chunks - want) / want
            problems = d.validate()
            if rel > 1e-6 or problems:
                ok = False
                if verbose:
                    print(f"{app}@mvl{m}: chunks {d.chunks} vs {want} "
                          f"(rel {rel:.2e}); validate: {problems}")
    if verbose:
        print("rvv chunk counts + body invariants:",
              "ok" if ok else "PROBLEMS")
    return ok


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.rvv",
        description="Decode an RVV v1.0 assembly kernel into the vector IR "
                    "and simulate it, or run the corpus cross-validation "
                    "gate (--check-all).")
    ap.add_argument("file", nargs="?", help="RVV assembly file (.s)")
    ap.add_argument("--check-all", action="store_true",
                    help="cross-validate the src/repro/asm corpus against "
                         "the hand-coded tracegen bodies at every MVL in "
                         f"{CHECK_MVLS} (the ci.sh rvv-crossval gate)")
    ap.add_argument("--mvl", type=int, default=64,
                    help="hardware MVL in 64-bit elements (default 64)")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--avl", type=int, default=None,
                    help="initial a0 (application vector length) for "
                         "kernels that take AVL as an argument")
    ap.add_argument("--expand", action="store_true",
                    help="ignore .chunk and expand every loop concretely")
    args = ap.parse_args(argv)

    if args.check_all:
        return 0 if check_all() else 1
    if not args.file:
        ap.error("need an assembly file or --check-all")

    from repro.core import engine as eng
    cfg = eng.VectorEngineConfig(mvl=args.mvl, lanes=args.lanes)
    d = decode_file(args.file, args.mvl, cfg, expand=args.expand,
                    avl=args.avl)
    tr, pro = d.trace, d.prologue
    print(f"{args.file}: decoded at mvl={args.mvl} lanes={args.lanes} "
          f"(VLMAX={d.vlmax})")
    print(f"  prologue: {len(pro)} IR entries; chunk body: {len(tr)} "
          f"entries x {d.chunks:g} chunks")
    hist = {isa.KIND_NAMES[k]: int(c)
            for k, c in enumerate(isa.kind_histogram(tr)) if c}
    print(f"  body kinds: {hist}")
    print(f"  vector registers touched: {isa.trace_registers(tr)}; "
          f"element work/chunk: {int(tr.vl[tr.kind != isa.SCALAR_BLOCK].sum())}")
    problems = d.validate()
    print(f"  invariants: {'ok' if not problems else problems}")
    per_chunk = eng.steady_state_time(tr, cfg)
    total = eng.simulate(d.full_trace, cfg)["time"]
    print(f"  steady-state time/chunk: {per_chunk:.1f} cycles; "
          f"modeled kernel time: {d.chunks * per_chunk:.0f} cycles "
          f"(one-pass decode+sim of the decoded stream: {total:.0f})")
    return 0 if not problems else 1


if __name__ == "__main__":
    raise SystemExit(main())

