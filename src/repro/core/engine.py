"""Cycle-level decoupled vector-engine timing model (paper §3) as a lax.scan.

The gem5 event-driven model is reformulated as a *list-scheduler recurrence*:
every instruction's issue time is the max over its structural and data
constraints (scalar-core frontier, rename/ROB/queue slot availability, operand
readiness, FU availability, in-order gate), and its completion feeds those
same resources forward.  Ring buffers in the scan carry give ROB / physical-
register / issue-queue occupancy exactly, so the model reproduces the paper's
first-order effects:

  * start-up time = FU pipe depth + ceil(n_src / VRF read ports)  (§3.2.4)
  * one arithmetic instruction in flight across all lanes         (§3.2.3)
  * VMU serialization: one memory instruction at a time           (§3.2.5)
  * analytic cache/MSHR/DRAM model: miss rates derived from each
    access's stream footprint and the cache geometry, MSHR-gated
    gather concurrency, shared DRAM bandwidth (repro.core.memory)  (§3.2.5)
  * ring vs crossbar interconnect cost for slides/reductions      (§3.2.6)
  * decoupling: scalar core runs ahead, queues absorb slack       (§3.1)
  * vfirst/vpopc results stall the scalar core                    (§4.1.4)

Times are in vector-engine cycles (1 GHz -> 1 cycle = 1 ns); the scalar core
runs at 2 GHz dual-issue with latency-class costs.

All config knobs — including issue policy and interconnect topology — are
traced values, so one compiled scan serves every configuration and the whole
model vmaps over a config axis: ``simulate_batch`` runs a multi-config sweep
(e.g. the paper's 24-point Table 10 grid x 7 apps) as a handful of XLA
dispatches, with traces NOP-padded to power-of-two length buckets so repeat
sweeps hit the jit cache.
"""
from __future__ import annotations

import functools
import hashlib
from dataclasses import dataclass, fields

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa
from repro.core import memory

MAX_RING = 64  # static ring-buffer capacity (>= max rob/queue/phys-in-flight)


@dataclass(frozen=True)
class VectorEngineConfig:
    """Every knob of Table 10 (and §3.2) is a field here."""
    mvl: int = 256                 # max vector length, 64-bit elements
    lanes: int = 8
    phys_regs: int = 40            # >= 32 architectural
    rob_entries: int = 64
    queue_entries: int = 16        # per queue (arith / memory)
    ooo_issue: bool = False
    vrf_read_ports: int = 1
    vrf_line_bits: int = 512
    interconnect: str = "ring"     # "ring" | "crossbar"
    mem_ports: int = 1
    cache_line_bits: int = 512
    lat_l1: float = 4.0
    lat_l2: float = 12.0
    lat_dram: float = 100.0
    mshrs: int = 16
    l1_kb: int = 32
    l2_kb: int = 256
    # shared DRAM stream bandwidth (B/cycle); default is the calibrated
    # constant in repro.core.memory (single source of truth)
    dram_bw_bytes_cycle: float = memory.DRAM_BW_BYTES_PER_CYCLE
    scalar_freq_ghz: float = 2.0
    vector_freq_ghz: float = 1.0
    # scalar-core pipeline knobs (§3.1, repro.core.scalar_pipeline): the
    # issue width of the in-order scalar core, its branch mispredict penalty
    # (scalar-core cycles) and macro-op fusion.  They drive the event-based
    # scalar-baseline model AND the residual scalar blocks inside vectorized
    # code, so they are live batch axes like every other knob here.
    issue_width: int = 2
    branch_miss_penalty: float = 6.0
    fusion: bool = False
    dispatch_latency: float = 5.0  # scalar commit -> vector engine dispatch

    def __post_init__(self):
        """The scan's occupancy ring buffers are statically sized MAX_RING;
        a capacity beyond that silently wraps and corrupts every timing
        result, so reject it at construction."""
        for name, cap in (("rob_entries", self.rob_entries),
                          ("queue_entries", self.queue_entries),
                          ("phys_regs - 32", self.phys_regs - 32)):
            if cap > MAX_RING:
                raise ValueError(
                    f"{name}={cap} exceeds the engine ring capacity "
                    f"MAX_RING={MAX_RING}; raise engine.MAX_RING to model it")
        if self.phys_regs < 33:
            raise ValueError(
                f"phys_regs={self.phys_regs}: need >= 33 (32 architectural "
                "+ at least one rename register)")

    def label(self) -> str:
        """Result key: ``mvl{m}_l{l}`` plus one suffix per knob that differs
        from the Table-10 defaults — derived from the dataclass fields, so
        configs differing in *any* swept axis (LLC, MSHRs, DRAM bandwidth,
        ports, latencies, interconnect, ...) never collide.

        The label keys the DSE result cache (``repro.core.dse``), so float
        knobs must render round-trip exactly: ``%g`` keeps 6 significant
        digits, which would alias e.g. two ``dram_bw_bytes_cycle`` values
        differing in the 7th — those fall back to full-precision ``repr``.
        ``tests/test_dse.py`` asserts label uniqueness over ``SPACE_FULL``.
        """
        s = f"mvl{self.mvl}_l{self.lanes}"
        for f in fields(self):
            v = getattr(self, f.name)
            if f.name in ("mvl", "lanes") or v == f.default:
                continue
            if f.name == "ooo_issue":
                s += "_ooo"
            elif f.name == "fusion":
                s += "_fusion"
            elif f.name == "interconnect":
                s += f"_{v}"
            else:
                r = f"{v:g}"
                if isinstance(v, float) and float(r) != v:
                    r = repr(v)
                s += f"_{f.name}{r}"
        return s


# Calibrated latency classes (fit against the paper's §5 speedup anchors; see
# benchmarks/calibrate.py provenance).  Scalar: effective dependent-chain
# cycles per instruction at 2 GHz.  Vector: FU pipe depth (start-up) and
# per-element throughput cost in cycles/element/lane.
SCALAR_CYCLES = np.array([1.1, 3.0, 20.0, 24.0], np.float32)   # per FU class
VEC_PIPE_DEPTH = np.array([2.0, 4.0, 8.0, 8.0], np.float32)
VEC_ELEM_CYCLES = np.array([1.0, 1.0, 2.0, 2.0], np.float32)

# Residual scalar blocks inside vectorized code run on the same scalar core
# the baseline does, so the scalar-pipeline knobs perturb them too.
# SCALAR_CYCLES are effective per-class costs at the DEFAULT core (6-cycle
# mispredict penalty, no fusion); the knobs contribute a *delta* around that
# default — exactly zero at the Table-10 defaults, so default-config vector
# timings are bitwise-unchanged by the knobs' existence.
SC_BLOCK_BRANCH_FRAC = 0.12    # branches per residual scalar instruction
SC_BLOCK_BMISS_RATE = 0.08     # mispredict rate of those branches
DEFAULT_BRANCH_MISS_PENALTY = 6.0
FUSION_SIMPLE_SAVE = 0.15      # simple-class cycles removed by macro-op fusion


# --------------------------------------------------------------------------
# cycle attribution (the collect_stats scan variant)
# --------------------------------------------------------------------------
# Every stall/execution cause the profiling scan attributes cycles to, in
# accumulator order.  The attribution is a *frontier decomposition*: the
# running completion frontier ``F = max(t_scalar, last_commit)`` is monotone,
# and each scan step's advance ``F_new - F_old`` is split into the wait that
# delayed issue (attributed to the binding constraint — the argmax of the
# issue equation), the execution time visible beyond the frontier (attributed
# to the executing module), and scalar-pipe work.  Summing the accumulators
# therefore reconstructs ``time`` exactly (float32 association aside) — the
# event-sum identity ``python -m repro.core.telemetry --smoke`` enforces.
STALL_KINDS = (
    "scalar_work",   # scalar-block work + scalar pipe carrying vector instrs
    "dep_scalar",    # visible cycles of scalar blocks consuming a vector->
                     # scalar result (coupling round-trips on critical path)
    "dispatch",      # issue gated by the scalar frontier + dispatch latency
    "rob_full",      # structural: no free ROB entry
    "phys_full",     # structural: no free physical (rename) register
    "aq_full",       # structural: arithmetic issue queue full
    "mq_full",       # structural: memory issue queue full
    "raw",           # RAW wait on a vector register operand
    "lane_wait",     # lane FU busy with an earlier arithmetic instruction
    "vmu_wait",      # VMU busy with an earlier memory instruction
    "inorder",       # in-order issue gate (older instr not yet issued)
    "exec_simple",   # visible execution: VARITH per FU class
    "exec_mul",
    "exec_div",
    "exec_trans",
    "exec_interconnect",  # visible execution: slides / reductions
    "exec_mask",          # visible execution: vfirst/vpopc mask->scalar
    "exec_move",          # visible execution: whole-register moves
    "exec_mem",           # visible execution: memory access (VMU) cycles
)
N_STALL = len(STALL_KINDS)
_S = {k: i for i, k in enumerate(STALL_KINDS)}


def _ring_read(ring, count, capacity):
    """Time at which the slot for the `count`-th allocation frees (0 if never
    yet full): value written `capacity` allocations ago."""
    idx = jnp.mod(count - capacity, MAX_RING)
    return jnp.where(count >= capacity, ring[idx], 0.0)


def _ring_write(ring, count, value):
    return ring.at[jnp.mod(count, MAX_RING)].set(value)


def _make_step(params, collect: bool = False):
    """Build the per-instruction scan step for one parameter vector.

    Everything configuration-dependent — including the formerly-static
    ``ooo``/``ring`` flags — is a traced value, so a single compiled
    executable serves every config and the step vmaps cleanly over a batch
    axis (``simulate_batch``).

    ``collect`` (a Python-level flag, resolved at trace time) appends the
    cycle-attribution accumulators (``STALL_KINDS`` vector + per-FU lane
    occupancy) to the carry and emits per-record ``(start, issue, complete,
    cause)`` outputs for timeline export.  With ``collect=False`` the traced
    jaxpr is the pre-profiler one — the default path stays bitwise-identical
    and keyed on the same executables.
    """
    (lanes, phys_extra, rob_entries, q_entries, read_ports, line_elems,
     mem_ports, lat_l1, lat_l2, lat_dram, scalar_scale, dispatch_lat,
     ooo_f, ring_f, l1_kb, l2_kb, mshrs_f, dram_line_cyc,
     bmiss_extra, fuse_save) = params
    sc_cost = jnp.asarray(SCALAR_CYCLES)
    pipe_depth = jnp.asarray(VEC_PIPE_DEPTH)
    elem_cost = jnp.asarray(VEC_ELEM_CYCLES)

    def step(carry, x):
        if collect:
            (reg_ready, rob_ring, n_rob, phys_ring, n_phys, aq_ring, n_aq,
             mq_ring, n_mq, t_scalar, lane_free, vmu_free, last_aq, last_mq,
             last_commit, scalar_res, busy_lane, busy_vmu,
             stall_acc, occ_fu) = carry
        else:
            (reg_ready, rob_ring, n_rob, phys_ring, n_phys, aq_ring, n_aq,
             mq_ring, n_mq, t_scalar, lane_free, vmu_free, last_aq, last_mq,
             last_commit, scalar_res, busy_lane, busy_vmu) = carry
        kind, vl, fu, n_src, src1, src2, dst, mpat, fp_kb, s_count, dep = x

        vlf = vl.astype(jnp.float32)
        # NOP padding rides the scalar path with s_count=0 / dep=False: it
        # advances no clock and writes no resource (padding invariance).
        is_scalar = (kind == isa.SCALAR_BLOCK) | (kind == isa.NOP)

        # ---- scalar block ---------------------------------------------------
        # per-class cost with the scalar-pipeline knob deltas: macro-op
        # fusion trims simple-class cycles, a non-default mispredict penalty
        # adds/removes branch-miss cycles per instruction.  Both deltas are
        # exactly 0.0 at the Table-10 defaults (bitwise-neutral).
        t_wait = jnp.where(dep, jnp.maximum(t_scalar, scalar_res), t_scalar)
        s_cf = s_count.astype(jnp.float32)
        eff_cost = sc_cost[fu] * (1.0 - fuse_save * (fu == 0))
        sc_time = s_cf * eff_cost * scalar_scale + s_cf * bmiss_extra
        t_scalar_s = t_wait + sc_time

        # ---- vector instruction --------------------------------------------
        # scalar pipe cost of carrying the vector instruction to commit
        t_scalar_v = t_scalar + sc_cost[0] * scalar_scale
        rob_slot = _ring_read(rob_ring, n_rob, rob_entries)
        phys_slot = _ring_read(phys_ring, n_phys, phys_extra)
        is_mem = (kind == isa.VLOAD) | (kind == isa.VSTORE)
        q_slot = jnp.where(is_mem,
                           _ring_read(mq_ring, n_mq, q_entries),
                           _ring_read(aq_ring, n_aq, q_entries))
        dispatch = jnp.maximum(jnp.maximum(t_scalar_v + dispatch_lat, rob_slot),
                               jnp.maximum(phys_slot, q_slot))

        r1 = jnp.where(src1 >= 0, reg_ready[jnp.maximum(src1, 0)], 0.0)
        r2 = jnp.where(src2 >= 0, reg_ready[jnp.maximum(src2, 0)], 0.0)
        ops_ready = jnp.maximum(r1, r2)

        fu_free = jnp.where(is_mem, vmu_free, lane_free)
        inorder = jnp.where(is_mem, last_mq, last_aq)
        issue = jnp.maximum(jnp.maximum(dispatch, ops_ready), fu_free)
        issue = jnp.where(ooo_f > 0, issue, jnp.maximum(issue, inorder))

        # start-up: pipe depth + VRF read-port serialization (§3.2.4)
        startup = pipe_depth[fu] + jnp.ceil(
            n_src.astype(jnp.float32) / read_ports)

        per_lane = jnp.ceil(vlf / lanes)
        exec_arith = per_lane * elem_cost[fu]
        # slides move each element one lane over: one extra hop either topology
        exec_slide = per_lane + 1.0
        hops = jnp.where(ring_f > 0, lanes - 1.0,
                         jnp.ceil(jnp.log2(jnp.maximum(lanes, 2.0))))
        exec_reduce = per_lane + hops + pipe_depth[fu]
        exec_move = per_lane
        exec_mask = per_lane + hops  # vfirst/vpopc reduce a mask to a scalar

        # analytic memory hierarchy (§3.2.5): miss probabilities derived from
        # the access's stream footprint x the cache geometry, MSHR-limited
        # miss overlap, and a shared DRAM bandwidth term — all traced, so the
        # LLC/MSHR knobs are live batch axes (repro.core.memory)
        exec_mem = memory.vector_access_cycles(
            vlf, mpat, fp_kb, line_elems, l1_kb, l2_kb, mshrs_f,
            lat_l1, lat_l2, lat_dram, dram_line_cyc, mem_ports)

        exec_c = jnp.select(
            [kind == isa.VARITH, kind == isa.VLOAD, kind == isa.VSTORE,
             kind == isa.VSLIDE, kind == isa.VREDUCE, kind == isa.VMASK_SCALAR,
             kind == isa.VMOVE],
            [exec_arith, exec_mem, exec_mem, exec_slide, exec_reduce,
             exec_mask, exec_move], 0.0)

        complete = issue + startup + exec_c
        commit = jnp.maximum(complete, last_commit)

        # ---- merge scalar/vector outcomes -----------------------------------
        t_scalar_n = jnp.where(is_scalar, t_scalar_s, t_scalar_v)
        upd = lambda old, new: jnp.where(is_scalar, old, new)

        reg_ready_n = jnp.where(
            is_scalar | (dst < 0), reg_ready,
            reg_ready.at[jnp.maximum(dst, 0)].set(complete))
        rob_ring_n = jnp.where(is_scalar, rob_ring,
                               _ring_write(rob_ring, n_rob, commit))
        phys_ring_n = jnp.where(is_scalar, phys_ring,
                                _ring_write(phys_ring, n_phys, commit))
        aq_ring_n = jnp.where(is_scalar | is_mem, aq_ring,
                              _ring_write(aq_ring, n_aq, issue))
        mq_ring_n = jnp.where(is_scalar | ~is_mem, mq_ring,
                              _ring_write(mq_ring, n_mq, issue))
        one = jnp.int32(1)
        carry_n = (
            reg_ready_n, rob_ring_n, upd(n_rob, n_rob + one),
            phys_ring_n, upd(n_phys, n_phys + one),
            aq_ring_n, upd(n_aq, jnp.where(is_mem, n_aq, n_aq + one)),
            mq_ring_n, upd(n_mq, jnp.where(is_mem, n_mq + one, n_mq)),
            t_scalar_n,
            upd(lane_free, jnp.where(is_mem, lane_free, complete)),
            upd(vmu_free, jnp.where(is_mem, complete, vmu_free)),
            upd(last_aq, jnp.where(is_mem, last_aq, issue)),
            upd(last_mq, jnp.where(is_mem, issue, last_mq)),
            upd(last_commit, commit),
            # vfirst/vpopc AND reductions deliver their result to the scalar
            # core (vfred* + vfmv.f.s): a later dep_scalar block waits on it
            upd(scalar_res,
                jnp.where((kind == isa.VMASK_SCALAR) | (kind == isa.VREDUCE),
                          complete, scalar_res)),
            busy_lane + jnp.where(is_scalar | is_mem, 0.0, startup + exec_c),
            busy_vmu + jnp.where(is_mem, startup + exec_c, 0.0),
        )
        if not collect:
            return carry_n, None

        # ---- cycle attribution (collect_stats only) -------------------------
        # Frontier decomposition: F = max(t_scalar, last_commit) is monotone;
        # this step advances it by delta = F_new - F_old, which is split
        # exactly (real arithmetic) into wait/exec/scalar pieces below — so
        # sum(stall_acc) == final time to float32 association tolerance.
        f_old = jnp.maximum(t_scalar, last_commit)
        # scalar block: the raw wait on a pending vector->scalar result is
        # always frontier-hidden (scalar_res <= last_commit <= F), so the
        # coupling cost surfaces as the dep block's *visible work* — route
        # it to dep_scalar instead of scalar_work when dep is set
        dep_vis = jnp.maximum(t_wait - f_old, 0.0)
        work_vis = jnp.maximum(t_scalar_s - jnp.maximum(t_wait, f_old), 0.0)
        sc_idx = jnp.where(dep, _S["dep_scalar"], _S["scalar_work"])
        # vector instruction: issue wait goes to the binding constraint of
        # the issue equation (structural fulls take precedence on ties, then
        # operand RAW, FU busy, the in-order gate; scalar-frontier dispatch
        # is the catch-all — issue is the max of exactly these candidates)
        cause = jnp.select(
            [issue == rob_slot, issue == phys_slot, issue == q_slot,
             issue == ops_ready, issue == fu_free,
             (ooo_f <= 0) & (issue == inorder)],
            [jnp.int32(_S["rob_full"]), jnp.int32(_S["phys_full"]),
             jnp.where(is_mem, _S["mq_full"], _S["aq_full"]),
             jnp.int32(_S["raw"]),
             jnp.where(is_mem, _S["vmu_wait"], _S["lane_wait"]),
             jnp.int32(_S["inorder"])],
            jnp.int32(_S["dispatch"]))
        exec_idx = jnp.select(
            [is_mem,
             (kind == isa.VSLIDE) | (kind == isa.VREDUCE),
             kind == isa.VMASK_SCALAR,
             kind == isa.VMOVE],
            [jnp.int32(_S["exec_mem"]), jnp.int32(_S["exec_interconnect"]),
             jnp.int32(_S["exec_mask"]), jnp.int32(_S["exec_move"])],
            jnp.int32(_S["exec_simple"]) + fu)
        wait_vis = jnp.maximum(issue - f_old, 0.0)
        exec_vis = jnp.maximum(complete - jnp.maximum(issue, f_old), 0.0)
        # scalar pipe running ahead of the engine: visible scalar work
        tail_vis = jnp.maximum(t_scalar_v - jnp.maximum(complete, f_old), 0.0)

        zero_vec = jnp.zeros((N_STALL,), jnp.float32)
        sc_delta = (zero_vec.at[_S["dep_scalar"]].add(dep_vis)
                    .at[sc_idx].add(work_vis))
        vec_delta = (zero_vec.at[cause].add(wait_vis)
                     .at[exec_idx].add(exec_vis)
                     .at[_S["scalar_work"]].add(tail_vis))
        stall_n = stall_acc + jnp.where(is_scalar, sc_delta, vec_delta)
        occ_n = occ_fu.at[fu].add(
            jnp.where(is_scalar | is_mem, 0.0, startup + exec_c))

        # per-record timeline spans: scalar (start, wait-end, work-end);
        # vector (scalar-commit, issue, complete)
        ys = (jnp.where(is_scalar, t_scalar, t_scalar_v),
              jnp.where(is_scalar, t_wait, issue),
              jnp.where(is_scalar, t_scalar_s, complete),
              jnp.where(is_scalar,
                        jnp.where(dep, _S["dep_scalar"], _S["scalar_work"]),
                        cause).astype(jnp.int32))
        return carry_n + (stall_n, occ_n), ys

    return step


def _init_carry():
    zero = jnp.float32(0.0)
    izero = jnp.int32(0)
    return (jnp.zeros(32, jnp.float32), jnp.zeros(MAX_RING, jnp.float32), izero,
            jnp.zeros(MAX_RING, jnp.float32), izero,
            jnp.zeros(MAX_RING, jnp.float32), izero,
            jnp.zeros(MAX_RING, jnp.float32), izero,
            zero, zero, zero, zero, zero, zero, zero, zero, zero)


def _metrics(carry) -> dict:
    t_scalar, last_commit = carry[9], carry[14]
    return {
        "time": jnp.maximum(t_scalar, last_commit),
        "t_scalar": t_scalar,
        "t_last_commit": last_commit,
        "lane_busy": carry[16],
        "vmu_busy": carry[17],
    }


def _init_carry_stats():
    return _init_carry() + (jnp.zeros(N_STALL, jnp.float32),
                            jnp.zeros(4, jnp.float32))


def _scan_core(xs, params):
    """One trace x one config, full-length scan -> timing dict."""
    carry, _ = jax.lax.scan(_make_step(params), _init_carry(), xs)
    return _metrics(carry)


def _profile_core(xs, params):
    """The collect_stats scan: same step arithmetic plus the attribution
    accumulators and per-record timeline outputs.  One extra jit key total
    (``_profile_jit``); pure jnp, so it vmaps like the default core."""
    carry, ys = jax.lax.scan(_make_step(params, collect=True),
                             _init_carry_stats(), xs)
    out = _metrics(carry)
    out["stalls"] = carry[18]
    out["occ_lane_fu"] = carry[19]
    return out, ys


def _chunk_core(carry, xs, params):
    """One fixed-size chunk of the scan, resumable: threading the carry
    through repeated calls is exactly the full scan, but every trace length
    reuses the same (batch, CHUNK)-shaped executable instead of compiling
    per length — the jit-cache memoization that makes repeat sweeps cheap."""
    carry, _ = jax.lax.scan(_make_step(params), carry, xs)
    return carry


_simulate_jit = jax.jit(_scan_core)
_chunk_batch_jit = jax.jit(jax.vmap(_chunk_core))
_profile_jit = jax.jit(_profile_core)


_SHARDED_JITS: dict[int, object] = {}


def _sharded_chunk_jit(ndev: int):
    """The batched chunk scan sharded over the config axis: an SPMD wrapper
    around the same vmapped ``_chunk_core``, so each device scans its slice
    of the batch and results are indistinguishable from the single-device
    path (the per-lane scan arithmetic is shared).

    Built lazily per device count; ``repro.distributed.sharding`` provides
    the version-compatible ``shard_map``.
    """
    f = _SHARDED_JITS.get(ndev)
    if f is None:
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.distributed.sharding import compat_shard_map
        # local_devices, not devices: in a multi-process job the mesh must
        # hold only this process's addressable devices
        mesh = Mesh(np.asarray(jax.local_devices()[:ndev]), ("cfg",))
        f = _SHARDED_JITS[ndev] = jax.jit(compat_shard_map(
            jax.vmap(_chunk_core), mesh, in_specs=P("cfg"),
            out_specs=P("cfg")))
    return f


def _dispatch_chunk_batch(carry, xs, params, batch_bucket: int):
    """Dispatch one chunk of the batched scan, sharding the config axis
    across local devices when there is more than one (and the power-of-two
    batch bucket divides evenly); otherwise the single-device vmapped path.

    This is the DSE sharding contract (docs/architecture.md): the config
    axis is embarrassingly parallel — no collectives cross the shard
    boundary — so a many-config sweep scales with device count while the
    one-device fallback keeps every existing caller bitwise unchanged.
    """
    ndev = jax.local_device_count()
    if ndev > 1 and batch_bucket % ndev == 0:
        return _sharded_chunk_jit(ndev)(carry, xs, params)
    return _chunk_batch_jit(carry, xs, params)

# Batched traces are NOP-padded to multiples of CHUNK and scanned chunk by
# chunk; the compilation key is (batch bucket, CHUNK) only.
CHUNK = 1024

_TRACE_FIELDS = ("kind", "vl", "fu", "n_src", "src1", "src2", "dst",
                 "mem_pattern", "footprint_kb", "scalar_count",
                 "dep_scalar")


def _trace_xs(trace: isa.Trace) -> tuple:
    return tuple(jnp.asarray(getattr(trace, f)) for f in _TRACE_FIELDS)


def _cfg_params_np(cfg: VectorEngineConfig) -> tuple:
    """Per-config parameter vector (np scalars: stackable for the batch axis)."""
    freq_ratio = cfg.vector_freq_ghz / cfg.scalar_freq_ghz
    scalar_scale = freq_ratio / cfg.issue_width
    # knob deltas around the default core (zero at defaults; see the
    # SC_BLOCK_* constants): extra vector-cycles per residual scalar instr
    # from a non-default mispredict penalty, and the fused simple-class save
    bmiss_extra = (SC_BLOCK_BRANCH_FRAC * SC_BLOCK_BMISS_RATE
                   * (cfg.branch_miss_penalty - DEFAULT_BRANCH_MISS_PENALTY)
                   * freq_ratio)
    fuse_save = FUSION_SIMPLE_SAVE if cfg.fusion else 0.0
    return (
        np.float32(cfg.lanes), np.int32(cfg.phys_regs - 32),
        np.int32(cfg.rob_entries), np.int32(cfg.queue_entries),
        np.float32(cfg.vrf_read_ports), np.float32(cfg.cache_line_bits / 64),
        np.float32(cfg.mem_ports), np.float32(cfg.lat_l1),
        np.float32(cfg.lat_l2), np.float32(cfg.lat_dram),
        np.float32(scalar_scale), np.float32(cfg.dispatch_latency),
        np.float32(1.0 if cfg.ooo_issue else 0.0),
        np.float32(1.0 if cfg.interconnect == "ring" else 0.0),
        np.float32(cfg.l1_kb), np.float32(cfg.l2_kb), np.float32(cfg.mshrs),
        np.float32(memory.dram_line_cycles(cfg.cache_line_bits,
                                           cfg.dram_bw_bytes_cycle)),
        np.float32(bmiss_extra), np.float32(fuse_save),
    )


# Bump when the scan-step arithmetic changes in a way the calibration
# constants below don't capture (new resource model, different recurrence):
# it invalidates every persistent DSE cache entry.
# v2: scalar-pipeline knobs (issue_width / branch_miss_penalty / fusion)
# entered the parameter vector and the scalar-block cost expression.
MODEL_VERSION = 2


def model_fingerprint() -> str:
    """Hash of the timing model's calibration state: the latency-class
    constants here plus the memory-model constants.  Part of the DSE result
    cache key, so a recalibration (benchmarks/calibrate.py edits these
    arrays) can never be served stale cached timings — the cache just goes
    cold.  ``MODEL_VERSION`` covers structural model changes the constants
    don't express."""
    h = hashlib.sha1()
    h.update(f"v{MODEL_VERSION}".encode())
    for a in (SCALAR_CYCLES, VEC_PIPE_DEPTH, VEC_ELEM_CYCLES):
        h.update(np.asarray(a).tobytes())
    for c in (memory.DRAM_BW_BYTES_PER_CYCLE, memory.DRAM_MLP,
              memory.PREFETCH_DEPTH):
        h.update(np.float32(c).tobytes())
    return h.hexdigest()[:8]


def config_fingerprint(cfg: VectorEngineConfig) -> str:
    """Hash of everything about a config the *timing model* consumes: the
    engine parameter vector (``_cfg_params_np``), which excludes knobs that
    only shape the trace (``mvl`` beyond its effect on the body).

    This is the DSE result cache's config key half: two configs that differ
    only in a timing-irrelevant way (e.g. ``mvl=128`` vs ``mvl=256`` for an
    app whose ``max_vl`` caps both at 64, producing the same clamped body)
    share a fingerprint, so the cache dedups their dispatches within a run.
    """
    h = hashlib.sha1()
    for p in _cfg_params_np(cfg):
        h.update(np.asarray(p).tobytes())
    return h.hexdigest()[:16]


def simulate(trace: isa.Trace, cfg: VectorEngineConfig,
             collect_stats: bool = False) -> dict:
    """Run the timing model; returns times in vector-engine cycles (=ns).

    With ``collect_stats=True`` the profiling scan runs instead (same step
    arithmetic — ``tests/test_telemetry.py`` pins the timing bitwise-equal)
    and the result additionally carries:

    * ``stalls``: ``{cause: cycles}`` over ``STALL_KINDS`` — sums to
      ``time`` (the event-sum identity),
    * ``occ_lane_fu``: lane-busy cycles per arithmetic FU class,
    * ``records``: per-record ``start``/``issue``/``complete`` numpy arrays
      plus the binding-constraint ``cause`` index (timeline export feedstock
      for ``repro.core.telemetry``).
    """
    params = tuple(jnp.asarray(p) for p in _cfg_params_np(cfg))
    if not collect_stats:
        out = _simulate_jit(_trace_xs(trace), params)
        return {k: float(v) for k, v in out.items()}
    out, ys = _profile_jit(_trace_xs(trace), params)
    res = {k: float(v) for k, v in out.items()
           if k not in ("stalls", "occ_lane_fu")}
    res["stalls"] = {k: float(v) for k, v in
                     zip(STALL_KINDS, np.asarray(out["stalls"]))}
    res["occ_lane_fu"] = [float(v) for v in np.asarray(out["occ_lane_fu"])]
    res["records"] = {
        "start": np.asarray(ys[0]), "issue": np.asarray(ys[1]),
        "complete": np.asarray(ys[2]), "cause": np.asarray(ys[3]),
    }
    return res


def _pow2_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _len_bucket(n: int) -> int:
    """Batched traces are padded up to a multiple of CHUNK (the scan is
    dispatched chunk by chunk, so length buckets cost padded *run* steps but
    never a recompile)."""
    return max(CHUNK, -(-n // CHUNK) * CHUNK)


def batch_bucket(n: int) -> int:
    """The power-of-two batch bucket a batch of ``n`` (trace, config) pairs
    pads to.  Together with ``CHUNK`` this is the *only* jit-compilation key
    of the batched path — the contract the serve layer
    (``repro.serve.sim_service``) builds on: prewarm one executable per
    bucket up to the service's ``max_batch`` and steady-state serving never
    recompiles."""
    return _pow2_bucket(n)


def trace_len_bucket(n: int) -> int:
    """The CHUNK-multiple length bucket a trace of ``n`` entries pads to.
    A longer trace costs more chunk *dispatches* (bucket // CHUNK), never a
    recompile — which is why request coalescing only needs to group by batch
    bucket, not by workload."""
    return _len_bucket(n)


def jit_cache_size() -> int:
    """Number of engine executables compiled so far (sequential + batched),
    or -1 when the installed JAX doesn't expose jit cache introspection
    (``_cache_size`` is a private API).

    The batched path's compilation key is (batch bucket, CHUNK) only: flags
    are traced, lengths are chunked, batch sizes are padded to powers of two.
    """
    try:
        n = int(_simulate_jit._cache_size() + _chunk_batch_jit._cache_size())
        n += int(_profile_jit._cache_size())
        n += sum(int(f._cache_size()) for f in _SHARDED_JITS.values())
        return n
    except AttributeError:
        return -1


def _run_batch_group(traces: list[isa.Trace], cfgs: list[VectorEngineConfig],
                     length: int, collect_times: bool = False):
    """Pad to `length` (a CHUNK multiple), pad the batch to a power of two
    (repeating the first element), then scan chunk by chunk, carrying the
    engine state between dispatches.

    With ``collect_times`` the running per-lane "time" plus the lane/VMU
    busy accumulators after every chunk are also returned (each
    [n_chunks, B]) — ``steady_state_time_batch`` reads the warmup checkpoint
    out of the middle of a single fused scan, and the busy checkpoints give
    marginal steady-state utilization for free (reads of the same carry the
    timing dispatch produces anyway, so timing stays bitwise-identical).
    """
    b = len(traces)
    bb = _pow2_bucket(b)
    stacked = isa.stack_traces(traces + [traces[0]] * (bb - b), length)
    xs_np = [getattr(stacked, f) for f in _TRACE_FIELDS]
    cols = list(zip(*(_cfg_params_np(c) for c in (cfgs + [cfgs[0]] * (bb - b)))))
    params = tuple(jnp.asarray(np.stack(col)) for col in cols)
    carry = jax.tree.map(
        lambda a: jnp.zeros((bb,) + a.shape, a.dtype), _init_carry())
    times, busy_l, busy_v = [], [], []
    for i in range(length // CHUNK):
        xs = tuple(jnp.asarray(a[:, i * CHUNK:(i + 1) * CHUNK]) for a in xs_np)
        carry = _dispatch_chunk_batch(carry, xs, params, bb)
        if collect_times:
            times.append(jnp.maximum(carry[9], carry[14]))
            busy_l.append(carry[16])
            busy_v.append(carry[17])
    out = {k: np.asarray(v) for k, v in _metrics(carry).items()}
    rows = [{k: float(v[i]) for k, v in out.items()} for i in range(b)]
    if collect_times:
        return (rows,
                np.stack([np.asarray(t) for t in times]),
                np.stack([np.asarray(t) for t in busy_l]),
                np.stack([np.asarray(t) for t in busy_v]))
    return rows


def _broadcast_pairs(traces, cfgs, noun: str = "traces"):
    """Pair up the two argument lists, broadcasting a length-1 list."""
    traces = list(traces)
    cfgs = list(cfgs)
    if len(traces) == 1 and len(cfgs) > 1:
        traces = traces * len(cfgs)
    if len(cfgs) == 1 and len(traces) > 1:
        cfgs = cfgs * len(traces)
    if len(traces) != len(cfgs):
        raise ValueError(f"{len(traces)} {noun} vs {len(cfgs)} configs")
    return traces, cfgs


def _group_by_length_bucket(traces) -> dict[int, list[int]]:
    groups: dict[int, list[int]] = {}
    for i, t in enumerate(traces):
        groups.setdefault(_len_bucket(len(t)), []).append(i)
    return groups


def simulate_batch(traces, cfgs) -> list[dict]:
    """Batched timing model: N (trace, config) pairs in a handful of
    XLA dispatches instead of N sequential ``simulate`` calls.

    ``traces`` and ``cfgs`` are lists; a length-1 list broadcasts against the
    other argument.  Pairs are grouped by bucketed trace length; each group
    is padded with timing-neutral NOPs, stacked, and run through the vmapped
    chunk scan.  Results match sequential ``simulate`` (same step arithmetic
    — the scan core is shared) and arrive in input order.
    """
    traces, cfgs = _broadcast_pairs(traces, cfgs)
    if not traces:
        return []
    results: list[dict] = [None] * len(traces)  # type: ignore[list-item]
    for length, idxs in sorted(_group_by_length_bucket(traces).items()):
        outs = _run_batch_group([traces[i] for i in idxs],
                                [cfgs[i] for i in idxs], length)
        for i, r in zip(idxs, outs):
            results[i] = r
    return results


def steady_state_time(body: isa.Trace, cfg: VectorEngineConfig,
                      warmup: int = 8, measure: int = 24) -> float:
    """Marginal steady-state time of one loop body (warmup removed)."""
    t1 = simulate(body.tile(warmup), cfg)["time"]
    t2 = simulate(body.tile(warmup + measure), cfg)["time"]
    return (t2 - t1) / measure


def steady_state_time_batch(bodies, cfgs, warmup: int = 8,
                            measure: int = 24,
                            with_util: bool = False) -> list:
    """Batched ``steady_state_time``: every (body, config) pair in a handful
    of chunked dispatches.

    The warmup and measurement runs are fused into one scan per pair: the
    warmup tiles are NOP-padded to a chunk boundary (timing-neutral, so the
    carry at the boundary equals the carry after the bare warmup), the
    warmup time is read from the running per-chunk checkpoint, and the
    measurement tiles continue in the same scan — bitwise identical to the
    sequential two-simulation recipe at ~60% of the steps.

    With ``with_util`` each entry is a dict ``{"steady_ns", "lane_util",
    "vmu_util"}`` — the utilizations are *marginal* over the measurement
    window (busy cycles accumulated past the warmup checkpoint / wall
    cycles of the window), read from the same carry, so requesting them
    never perturbs the timing.
    """
    bodies, cfgs = _broadcast_pairs(bodies, cfgs, noun="bodies")
    if not bodies:
        return []
    traces, w_chunks = [], []
    for body in bodies:
        warm = body.tile(warmup)
        wlen = _len_bucket(len(warm))
        traces.append(warm.pad_to(wlen).concat(body.tile(measure)))
        w_chunks.append(wlen // CHUNK)
    out: list = [0.0] * len(traces)
    for length, idxs in sorted(_group_by_length_bucket(traces).items()):
        rows, times, busy_l, busy_v = _run_batch_group(
            [traces[i] for i in idxs], [cfgs[i] for i in idxs], length,
            collect_times=True)
        for lane, i in enumerate(idxs):
            t1 = float(times[w_chunks[i] - 1, lane])
            steady = (rows[lane]["time"] - t1) / measure
            if not with_util:
                out[i] = steady
                continue
            wall = max(rows[lane]["time"] - t1, 1e-9)
            out[i] = {
                "steady_ns": steady,
                "lane_util": (rows[lane]["lane_busy"]
                              - float(busy_l[w_chunks[i] - 1, lane])) / wall,
                "vmu_util": (rows[lane]["vmu_busy"]
                             - float(busy_v[w_chunks[i] - 1, lane])) / wall,
            }
    return out


def scalar_time(trace: isa.Trace, cfg: VectorEngineConfig) -> float:
    """Latency-weighted scalar-core time for a pure-scalar trace (ns), with
    the same knob deltas the scan step applies to residual scalar blocks."""
    freq_ratio = cfg.vector_freq_ghz / cfg.scalar_freq_ghz
    scale = freq_ratio / cfg.issue_width
    bmiss_extra = (SC_BLOCK_BRANCH_FRAC * SC_BLOCK_BMISS_RATE
                   * (cfg.branch_miss_penalty - DEFAULT_BRANCH_MISS_PENALTY)
                   * freq_ratio)
    fuse_save = FUSION_SIMPLE_SAVE if cfg.fusion else 0.0
    mask = trace.kind == isa.SCALAR_BLOCK
    fu = trace.fu[mask]
    eff = SCALAR_CYCLES[fu] * (1.0 - fuse_save * (fu == 0))
    return float(np.sum(trace.scalar_count[mask] * eff * scale
                        + trace.scalar_count[mask] * bmiss_extra))
