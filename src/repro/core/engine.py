"""Cycle-level decoupled vector-engine timing model (paper §3) as a lax.scan.

The gem5 event-driven model is reformulated as a *list-scheduler recurrence*:
every instruction's issue time is the max over its structural and data
constraints (scalar-core frontier, rename/ROB/queue slot availability, operand
readiness, FU availability, in-order gate), and its completion feeds those
same resources forward.  Ring buffers in the scan carry give ROB / physical-
register / issue-queue occupancy exactly, so the model reproduces the paper's
first-order effects:

  * start-up time = FU pipe depth + ceil(n_src / VRF read ports)  (§3.2.4)
  * one arithmetic instruction in flight across all lanes         (§3.2.3)
  * VMU serialization: one memory instruction at a time           (§3.2.5)
  * ring vs crossbar interconnect cost for slides/reductions      (§3.2.6)
  * decoupling: scalar core runs ahead, queues absorb slack       (§3.1)
  * vfirst/vpopc results stall the scalar core                    (§4.1.4)

Times are in vector-engine cycles (1 GHz -> 1 cycle = 1 ns); the scalar core
runs at 2 GHz dual-issue with latency-class costs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa

MAX_RING = 64  # static ring-buffer capacity (>= max rob/queue/phys-in-flight)


@dataclass(frozen=True)
class VectorEngineConfig:
    """Every knob of Table 10 (and §3.2) is a field here."""
    mvl: int = 256                 # max vector length, 64-bit elements
    lanes: int = 8
    phys_regs: int = 40            # >= 32 architectural
    rob_entries: int = 64
    queue_entries: int = 16        # per queue (arith / memory)
    ooo_issue: bool = False
    vrf_read_ports: int = 1
    vrf_line_bits: int = 512
    interconnect: str = "ring"     # "ring" | "crossbar"
    mem_ports: int = 1
    cache_line_bits: int = 512
    lat_l1: float = 4.0
    lat_l2: float = 12.0
    lat_dram: float = 100.0
    mshrs: int = 16
    l2_kb: int = 256
    scalar_freq_ghz: float = 2.0
    vector_freq_ghz: float = 1.0
    scalar_ipc: float = 2.0
    dispatch_latency: float = 5.0  # scalar commit -> vector engine dispatch

    def label(self) -> str:
        return f"mvl{self.mvl}_l{self.lanes}"


# Calibrated latency classes (fit against the paper's §5 speedup anchors; see
# benchmarks/calibrate.py provenance).  Scalar: effective dependent-chain
# cycles per instruction at 2 GHz.  Vector: FU pipe depth (start-up) and
# per-element throughput cost in cycles/element/lane.
SCALAR_CYCLES = np.array([1.1, 3.0, 20.0, 24.0], np.float32)   # per FU class
VEC_PIPE_DEPTH = np.array([2.0, 4.0, 8.0, 8.0], np.float32)
VEC_ELEM_CYCLES = np.array([1.0, 1.0, 2.0, 2.0], np.float32)


def _ring_read(ring, count, capacity):
    """Time at which the slot for the `count`-th allocation frees (0 if never
    yet full): value written `capacity` allocations ago."""
    idx = jnp.mod(count - capacity, MAX_RING)
    return jnp.where(count >= capacity, ring[idx], 0.0)


def _ring_write(ring, count, value):
    return ring.at[jnp.mod(count, MAX_RING)].set(value)


@functools.partial(jax.jit, static_argnames=("ooo", "ring_ic"))
def _simulate(xs, params, ooo: bool, ring_ic: bool):
    (lanes, phys_extra, rob_entries, q_entries, read_ports, line_elems,
     mem_ports, lat_l1, lat_l2, lat_dram, scalar_scale, dispatch_lat,
     sc_cost, pipe_depth, elem_cost) = params

    def step(carry, x):
        (reg_ready, rob_ring, n_rob, phys_ring, n_phys, aq_ring, n_aq,
         mq_ring, n_mq, t_scalar, lane_free, vmu_free, last_aq, last_mq,
         last_commit, scalar_res, busy_lane, busy_vmu) = carry
        kind, vl, fu, n_src, src1, src2, dst, mpat, m1, m2, s_count, dep = x

        vlf = vl.astype(jnp.float32)
        is_scalar = kind == isa.SCALAR_BLOCK

        # ---- scalar block ---------------------------------------------------
        t_wait = jnp.where(dep, jnp.maximum(t_scalar, scalar_res), t_scalar)
        sc_time = s_count.astype(jnp.float32) * sc_cost[fu] * scalar_scale
        t_scalar_s = t_wait + sc_time

        # ---- vector instruction --------------------------------------------
        # scalar pipe cost of carrying the vector instruction to commit
        t_scalar_v = t_scalar + sc_cost[0] * scalar_scale
        rob_slot = _ring_read(rob_ring, n_rob, rob_entries)
        phys_slot = _ring_read(phys_ring, n_phys, phys_extra)
        is_mem = (kind == isa.VLOAD) | (kind == isa.VSTORE)
        q_slot = jnp.where(is_mem,
                           _ring_read(mq_ring, n_mq, q_entries),
                           _ring_read(aq_ring, n_aq, q_entries))
        dispatch = jnp.maximum(jnp.maximum(t_scalar_v + dispatch_lat, rob_slot),
                               jnp.maximum(phys_slot, q_slot))

        r1 = jnp.where(src1 >= 0, reg_ready[jnp.maximum(src1, 0)], 0.0)
        r2 = jnp.where(src2 >= 0, reg_ready[jnp.maximum(src2, 0)], 0.0)
        ops_ready = jnp.maximum(r1, r2)

        fu_free = jnp.where(is_mem, vmu_free, lane_free)
        inorder = jnp.where(is_mem, last_mq, last_aq)
        issue = jnp.maximum(jnp.maximum(dispatch, ops_ready), fu_free)
        if not ooo:
            issue = jnp.maximum(issue, inorder)

        # start-up: pipe depth + VRF read-port serialization (§3.2.4)
        startup = pipe_depth[fu] + jnp.ceil(
            n_src.astype(jnp.float32) / read_ports)

        per_lane = jnp.ceil(vlf / lanes)
        exec_arith = per_lane * elem_cost[fu]
        # slides move each element one lane over: one extra hop either topology
        exec_slide = per_lane + 1.0
        hops = (lanes - 1.0) if ring_ic else jnp.ceil(jnp.log2(jnp.maximum(lanes, 2.0)))
        exec_reduce = per_lane + hops + pipe_depth[fu]
        exec_move = per_lane
        exec_mask = per_lane + hops  # vfirst/vpopc reduce a mask to a scalar

        exp_lat = lat_l1 + m1 * (lat_l2 + m2 * lat_dram)
        lines = jnp.ceil(vlf / line_elems)
        # DRAM-missing lines pay a bandwidth term (~8 cycles/line at DDR3
        # rates), not just latency: this is what makes the paper's Fig-10
        # LLC-size study visible (hit-under-miss hides latency, not BW)
        line_cost = 1.0 + m1 * m2 * 8.0
        exec_unit = exp_lat + lines * line_cost / mem_ports
        exec_gather = exp_lat + vlf * (1.0 + m1 * m2 * 2.0) / mem_ports
        exec_mem = jnp.where(mpat == isa.MEM_UNIT, exec_unit, exec_gather)

        exec_c = jnp.select(
            [kind == isa.VARITH, kind == isa.VLOAD, kind == isa.VSTORE,
             kind == isa.VSLIDE, kind == isa.VREDUCE, kind == isa.VMASK_SCALAR,
             kind == isa.VMOVE],
            [exec_arith, exec_mem, exec_mem, exec_slide, exec_reduce,
             exec_mask, exec_move], 0.0)

        complete = issue + startup + exec_c
        commit = jnp.maximum(complete, last_commit)

        # ---- merge scalar/vector outcomes -----------------------------------
        t_scalar_n = jnp.where(is_scalar, t_scalar_s, t_scalar_v)
        upd = lambda old, new: jnp.where(is_scalar, old, new)

        reg_ready_n = jnp.where(
            is_scalar | (dst < 0), reg_ready,
            reg_ready.at[jnp.maximum(dst, 0)].set(complete))
        rob_ring_n = jnp.where(is_scalar, rob_ring,
                               _ring_write(rob_ring, n_rob, commit))
        phys_ring_n = jnp.where(is_scalar, phys_ring,
                                _ring_write(phys_ring, n_phys, commit))
        aq_ring_n = jnp.where(is_scalar | is_mem, aq_ring,
                              _ring_write(aq_ring, n_aq, issue))
        mq_ring_n = jnp.where(is_scalar | ~is_mem, mq_ring,
                              _ring_write(mq_ring, n_mq, issue))
        one = jnp.int32(1)
        carry_n = (
            reg_ready_n, rob_ring_n, upd(n_rob, n_rob + one),
            phys_ring_n, upd(n_phys, n_phys + one),
            aq_ring_n, upd(n_aq, jnp.where(is_mem, n_aq, n_aq + one)),
            mq_ring_n, upd(n_mq, jnp.where(is_mem, n_mq + one, n_mq)),
            t_scalar_n,
            upd(lane_free, jnp.where(is_mem, lane_free, complete)),
            upd(vmu_free, jnp.where(is_mem, complete, vmu_free)),
            upd(last_aq, jnp.where(is_mem, last_aq, issue)),
            upd(last_mq, jnp.where(is_mem, issue, last_mq)),
            upd(last_commit, commit),
            upd(scalar_res,
                jnp.where(kind == isa.VMASK_SCALAR, complete, scalar_res)),
            busy_lane + jnp.where(is_scalar | is_mem, 0.0, startup + exec_c),
            busy_vmu + jnp.where(is_mem, startup + exec_c, 0.0),
        )
        return carry_n, commit

    zero = jnp.float32(0.0)
    izero = jnp.int32(0)
    carry0 = (jnp.zeros(32, jnp.float32), jnp.zeros(MAX_RING, jnp.float32), izero,
              jnp.zeros(MAX_RING, jnp.float32), izero,
              jnp.zeros(MAX_RING, jnp.float32), izero,
              jnp.zeros(MAX_RING, jnp.float32), izero,
              zero, zero, zero, zero, zero, zero, zero, zero, zero)
    carry, commits = jax.lax.scan(step, carry0, xs)
    t_scalar, last_commit = carry[9], carry[14]
    return {
        "time": jnp.maximum(t_scalar, last_commit),
        "t_scalar": t_scalar,
        "t_last_commit": last_commit,
        "lane_busy": carry[16],
        "vmu_busy": carry[17],
    }


def simulate(trace: isa.Trace, cfg: VectorEngineConfig) -> dict:
    """Run the timing model; returns times in vector-engine cycles (=ns)."""
    xs = (
        jnp.asarray(trace.kind), jnp.asarray(trace.vl), jnp.asarray(trace.fu),
        jnp.asarray(trace.n_src), jnp.asarray(trace.src1),
        jnp.asarray(trace.src2), jnp.asarray(trace.dst),
        jnp.asarray(trace.mem_pattern), jnp.asarray(trace.miss_l1),
        jnp.asarray(trace.miss_l2), jnp.asarray(trace.scalar_count),
        jnp.asarray(trace.dep_scalar),
    )
    freq_ratio = cfg.vector_freq_ghz / cfg.scalar_freq_ghz
    scalar_scale = freq_ratio / cfg.scalar_ipc
    params = (
        jnp.float32(cfg.lanes), jnp.int32(cfg.phys_regs - 32),
        jnp.int32(cfg.rob_entries), jnp.int32(cfg.queue_entries),
        jnp.float32(cfg.vrf_read_ports), jnp.float32(cfg.cache_line_bits / 64),
        jnp.float32(cfg.mem_ports), jnp.float32(cfg.lat_l1),
        jnp.float32(cfg.lat_l2), jnp.float32(cfg.lat_dram),
        jnp.float32(scalar_scale), jnp.float32(cfg.dispatch_latency),
        jnp.asarray(SCALAR_CYCLES), jnp.asarray(VEC_PIPE_DEPTH),
        jnp.asarray(VEC_ELEM_CYCLES),
    )
    out = _simulate(xs, params, bool(cfg.ooo_issue), cfg.interconnect == "ring")
    return {k: float(v) for k, v in out.items()}


def steady_state_time(body: isa.Trace, cfg: VectorEngineConfig,
                      warmup: int = 8, measure: int = 24) -> float:
    """Marginal steady-state time of one loop body (warmup removed)."""
    t1 = simulate(body.tile(warmup), cfg)["time"]
    t2 = simulate(body.tile(warmup + measure), cfg)["time"]
    return (t2 - t1) / measure


def scalar_time(trace: isa.Trace, cfg: VectorEngineConfig) -> float:
    """Latency-weighted scalar-core time for a pure-scalar trace (ns)."""
    freq_ratio = cfg.vector_freq_ghz / cfg.scalar_freq_ghz
    scale = freq_ratio / cfg.scalar_ipc
    mask = trace.kind == isa.SCALAR_BLOCK
    return float(np.sum(
        trace.scalar_count[mask] * SCALAR_CYCLES[trace.fu[mask]] * scale))
