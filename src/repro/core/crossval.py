"""Frontend-agnostic cross-validation: derived traces vs hand-coded bodies.

Every non-hand trace frontend — the jaxpr lowering (``repro.core.frontend``)
and the RVV assembly decoder (``repro.core.rvv``) — must reproduce the
hand-coded characterization bodies in ``tracegen`` before its apps are
trusted in sweeps.  This module is the one shared contract (extracted from
the jaxpr frontend, which originally carried it):

| property | tolerance |
|---|---|
| instruction-kind histogram | exact |
| FU histogram over ``VARITH`` | exact |
| memory-pattern histogram over loads/stores | exact |
| summed vector length (element work) | exact |
| total scalar count + ``dep_scalar`` count | exact |
| register pressure | fits the 32-reg file, within ±16 of hand-coded |
| steady-state time (per config) | within ``TIME_RTOL`` (5%) |

A frontend plugs in with a single callable ``derive(app, eff_mvl, cfg) ->
(trace, regs_used, max_live)``; the timing comparison for every (app, cfg)
pair runs as one ``steady_state_time_batch`` call, so a many-config gate
(e.g. the RVV per-MVL sweep) stays a handful of XLA dispatches.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import isa

N_LOGICAL_REGS = 32   # the engine's register-ready scoreboard size
TIME_RTOL = 0.05      # steady-state-time tolerance
REGS_ATOL = 16        # |derived regs - hand regs| tolerance


@dataclass
class CrossValReport:
    app: str
    kinds_ok: bool       # instruction-kind histogram: exact
    fu_ok: bool          # FU histogram over VARITH: exact
    pattern_ok: bool     # memory-pattern histogram over loads/stores: exact
    elems_ok: bool       # summed vector length (element work): exact
    scalar_ok: bool      # total scalar_count and dep_scalar count: exact
    pressure_ok: bool    # fits the register file, close to hand-coded
    hand_regs: int
    derived_regs: int
    time_hand: float = 0.0
    time_derived: float = 0.0
    cfg_label: str = ""
    fingerprint_eq: bool = False   # trace bitwise-identical to hand-coded

    @property
    def time_rel_err(self) -> float:
        return abs(self.time_derived - self.time_hand) / max(self.time_hand,
                                                             1e-9)

    @property
    def ok(self) -> bool:
        return (self.kinds_ok and self.fu_ok and self.pattern_ok
                and self.elems_ok and self.scalar_ok and self.pressure_ok
                and self.time_rel_err <= TIME_RTOL)


def static_report(app_name: str, hand: isa.Trace, derived: isa.Trace,
                  regs_used: int, max_live: int,
                  cfg_label: str = "") -> CrossValReport:
    """The static half of the contract (everything but timing)."""
    d = derived
    vmask = lambda t: t.kind != isa.SCALAR_BLOCK
    memmask = lambda t: (t.kind == isa.VLOAD) | (t.kind == isa.VSTORE)
    kinds_ok = bool(np.array_equal(isa.kind_histogram(hand),
                                   isa.kind_histogram(d)))
    fu_ok = bool(np.array_equal(
        np.bincount(hand.fu[hand.kind == isa.VARITH], minlength=4),
        np.bincount(d.fu[d.kind == isa.VARITH], minlength=4)))
    pattern_ok = bool(np.array_equal(
        np.bincount(hand.mem_pattern[memmask(hand)], minlength=3),
        np.bincount(d.mem_pattern[memmask(d)], minlength=3)))
    elems_ok = int(hand.vl[vmask(hand)].sum()) == int(d.vl[vmask(d)].sum())
    scalar_ok = (int(hand.scalar_count.sum()) == int(d.scalar_count.sum())
                 and int(hand.dep_scalar.sum()) == int(d.dep_scalar.sum()))
    hand_regs = isa.trace_registers(hand)
    pressure_ok = (max_live <= N_LOGICAL_REGS
                   and abs(regs_used - hand_regs) <= REGS_ATOL)
    fp_eq = (len(hand) == len(d)
             and isa.trace_fingerprint(hand) == isa.trace_fingerprint(d))
    return CrossValReport(app_name, kinds_ok, fu_ok, pattern_ok, elems_ok,
                          scalar_ok, pressure_ok, hand_regs, regs_used,
                          cfg_label=cfg_label, fingerprint_eq=fp_eq)


def cross_validate(derive, apps, cfgs) -> list[CrossValReport]:
    """Derived-vs-hand-coded contract for ``apps`` x ``cfgs``.

    ``derive(app, eff_mvl, cfg)`` returns the frontend's
    ``(trace, regs_used, max_live)`` for one loop-body chunk.  The timing
    comparison for every (app, cfg) pair runs as one batch.
    """
    from repro.core import engine as eng
    from repro.core import suite, tracegen
    reports, bodies, pair_cfgs = [], [], []
    for cfg in cfgs:
        for app in apps:
            eff = suite.effective_mvl(app, cfg)
            hand = tracegen.body_for(app, eff, cfg)
            trace, regs_used, max_live = derive(app, eff, cfg)
            reports.append(static_report(app, hand, trace, regs_used,
                                         max_live, cfg_label=cfg.label()))
            bodies += [hand, trace]
            pair_cfgs += [cfg, cfg]
    times = eng.steady_state_time_batch(bodies, pair_cfgs)
    for r, i in zip(reports, range(0, len(times), 2)):
        r.time_hand, r.time_derived = times[i], times[i + 1]
    return reports


@dataclass
class RoundTripReport:
    """One emit→decode round trip: the codegen-emitted assembly decoded at
    one configuration vs the direct jaxpr lowering of the same kernel."""
    app: str
    mvl: int
    fingerprint_eq: bool     # decoded body bitwise-equal to the lowering
    chunks_eq: bool          # decoder trip count == characterized closed form
    valid: bool              # isa.validate_trace clean (prologue defs live)
    problems: list

    @property
    def ok(self) -> bool:
        return self.fingerprint_eq and self.chunks_eq and self.valid


def round_trip_app(app_name: str, text: str | None = None,
                   mvls=None) -> list[RoundTripReport]:
    """Round-trip one app: emit (or take ``text``), decode at every MVL,
    and hold the decoded chunk body to the direct jaxpr lowering —
    fingerprint-equal trace, bitwise-equal chunk count, clean invariants."""
    from repro.core import codegen, engine as eng, frontend, rvv, suite
    from repro.core import tracegen
    if text is None:
        text = codegen.emit_app(app_name)
    if mvls is None:
        mvls = rvv.CHECK_MVLS
    app = tracegen.app_for(app_name)
    out = []
    for m in mvls:
        cfg = eng.VectorEngineConfig(mvl=m, lanes=4)
        eff = suite.effective_mvl(app.name, cfg)
        problems: list[str] = []
        d = rvv.decode(text, eff, cfg, path=f"<emit:{app.name}>")
        want = frontend.derived_body(app.name, eff, cfg).trace
        fp_eq = (len(d.trace) == len(want)
                 and isa.trace_fingerprint(d.trace)
                 == isa.trace_fingerprint(want))
        if not fp_eq:
            problems.append("decoded body != jaxpr lowering")
        chunks_eq = d.chunks == float(app.chunks(eff))
        if not chunks_eq:
            problems.append(f"chunks {d.chunks!r} != "
                            f"{float(app.chunks(eff))!r}")
        invariants = d.validate()
        problems += invariants
        out.append(RoundTripReport(app.name, m, fp_eq, chunks_eq,
                                   not invariants, problems))
    return out


def round_trip_all(apps=None, mvls=None) -> list[RoundTripReport]:
    """The codegen-roundtrip contract over every app with a jaxpr
    ``kernel=`` spec (``python -m repro.core.codegen --check-all``)."""
    from repro.core import tracegen
    if apps is None:
        apps = [a for a in sorted(tracegen.APPS)
                if tracegen.APPS[a].kernel is not None]
    reports = []
    for app in apps:
        reports += round_trip_app(app, mvls=mvls)
    return reports


def print_round_trips(reports: list[RoundTripReport], title: str) -> bool:
    """Render the round-trip gate table; returns the overall verdict."""
    print(f"{'app':16s} {'mvl':>4s} {'fingerprint':>12s} {'chunks':>7s} "
          f"{'valid':>6s}  ok")
    ok = True
    for r in reports:
        ok &= r.ok
        print(f"{r.app:16s} {r.mvl:4d} {str(r.fingerprint_eq):>12s} "
              f"{str(r.chunks_eq):>7s} {str(r.valid):>6s}  "
              f"{'ok' if r.ok else 'FAIL: ' + '; '.join(r.problems)}")
    print(f"\n{title}:", "ROUND-TRIPS" if ok else "MISMATCH")
    return ok


def print_reports(reports: list[CrossValReport], title: str) -> bool:
    """Render the gate table; returns the overall verdict."""
    print(f"{'app':16s} {'config':>14s} {'kinds':>6s} {'fu':>4s} {'mem':>4s} "
          f"{'elems':>6s} {'scalar':>7s} {'regs h/d':>9s} {'time err':>9s}  ok")
    ok = True
    for r in reports:
        ok &= r.ok
        print(f"{r.app:16s} {r.cfg_label:>14s} {str(r.kinds_ok):>6s} "
              f"{str(r.fu_ok):>4s} {str(r.pattern_ok):>4s} "
              f"{str(r.elems_ok):>6s} {str(r.scalar_ok):>7s} "
              f"{r.hand_regs:4d}/{r.derived_regs:<4d} "
              f"{r.time_rel_err:8.2%}  {'ok' if r.ok else 'FAIL'}")
    print(f"\n{title}:", "CONSISTENT" if ok else "MISMATCH")
    return ok
