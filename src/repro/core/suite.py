"""RiVec suite timing API: end-to-end modeled runtimes and speedups (§5).

``speedup(app, cfg)`` reproduces the paper's Figures 4-10 quantity: scalar
runtime / vectorized runtime on a given vector-engine configuration.  The
scalar side is the event-based dual-issue in-order pipeline model
(``repro.core.scalar_pipeline``, §3.1) driven by the config's scalar-core
knobs; the vector side is ``chunks x steady-state(loop body)`` from the
cycle-level engine.

A compute-bound app beats the scalar core and an LLC upgrade helps the
memory-stressed ones (docs/calibration.md has the full fidelity table):

>>> from repro.core import engine as eng
>>> speedup("blackscholes", eng.VectorEngineConfig(mvl=64, lanes=8)) > 2.0
True
>>> small = speedup("streamcluster", eng.VectorEngineConfig(mvl=64, lanes=4))
>>> big = speedup("streamcluster",
...               eng.VectorEngineConfig(mvl=64, lanes=4, l2_kb=1024))
>>> big > small
True
"""
from __future__ import annotations

from repro.core import engine as eng
from repro.core import tracegen

from repro.core import scalar_pipeline as _sp


def effective_mvl(app_name: str, cfg: eng.VectorEngineConfig) -> int:
    """The MVL a body actually runs at: the configured MVL clamped to the
    app's largest requested VL.  Both the loop-body trace and the chunk
    count use this one value (they previously disagreed: bodies were built
    at the raw ``cfg.mvl`` while ``chunks`` clamped)."""
    return min(cfg.mvl, tracegen.app_for(app_name).max_vl)


def scalar_runtime_ns(app_name: str,
                      cfg: eng.VectorEngineConfig | None = None) -> float:
    """Modeled scalar-version runtime (ns) from the event-based scalar
    pipeline model (``repro.core.scalar_pipeline``): per-instruction-class
    issue/RAW/branch/structural/memory events on the config's scalar core
    (``None``: the default 2 GHz dual-issue core).  Trace-source variants
    (``"<app>:asm"``) share the base app's scalar baseline — the scalar
    version of the program is the same either way."""
    return _sp.scalar_runtime_ns(app_name, cfg)


def vector_runtime_from_per_chunk(app_name: str, cfg: eng.VectorEngineConfig,
                                  body, per_chunk: float) -> float:
    """Whole-app modeled vector runtime from one cached/steady per-chunk time:
    ``chunks x per_chunk`` plus the residual (non-amortized) scalar work.

    This is the derivation half of the suite's timing pipeline — pure
    arithmetic over the (app, cfg, body) cell, shared by ``speedup_batch``,
    ``dse.explore`` and the simulation service so cached and simulated
    answers agree bitwise.
    """
    app = tracegen.app_for(app_name)
    mvl = effective_mvl(app_name, cfg)
    chunks = tracegen.chunks_for(app_name, mvl, cfg)
    # counts at the *effective* MVL — body_for/chunks_for clamp to the app's
    # max VL, so the residual derivation must too (cfg.mvl here made the
    # residual inconsistent whenever cfg.mvl > app.max_vl)
    counts = app.counts(mvl)
    # residual scalar work not amortized per chunk (s0-like constant part)
    per_chunk_scalar = sum(
        r for r in body.scalar_count)  # instrs already inside the body
    residual = max(counts.scalar_instrs - per_chunk_scalar * chunks, 0.0)
    # ns per residual instruction on the config's scalar core:
    # cycles / scalar clock / issue width (0.25 on the default 2 GHz
    # dual-issue core)
    res_scale = 1.0 / (cfg.scalar_freq_ghz * cfg.issue_width)
    return float(chunks * per_chunk
                 + residual * eng.SCALAR_CYCLES[0] * res_scale)


# back-compat alias (pre-PR-6 name)
_vector_runtime_from_per_chunk = vector_runtime_from_per_chunk


def vector_runtime_ns(app_name: str, cfg: eng.VectorEngineConfig) -> float:
    body = tracegen.body_for(app_name, effective_mvl(app_name, cfg), cfg)
    per_chunk = eng.steady_state_time(body, cfg)
    return vector_runtime_from_per_chunk(app_name, cfg, body, per_chunk)


def speedup(app_name: str, cfg: eng.VectorEngineConfig) -> float:
    return scalar_runtime_ns(app_name, cfg) / vector_runtime_ns(app_name, cfg)


def speedup_batch(pairs: list[tuple[str, eng.VectorEngineConfig]]) -> list[float]:
    """Speedups for N (app, config) pairs via the batched engine: the whole
    list is two ``simulate_batch`` calls (a handful of XLA dispatches),
    not 2N sequential simulations.  The scalar side is per-pair (the
    config's scalar-core knobs matter) but memoized per (app, scalar knobs),
    so a sweep over vector-side knobs still computes each scalar runtime
    once."""
    bodies = [tracegen.body_for(a, effective_mvl(a, c), c) for a, c in pairs]
    per_chunk = eng.steady_state_time_batch(bodies, [c for _, c in pairs])
    return [scalar_runtime_ns(a, c) / vector_runtime_from_per_chunk(a, c, b, pc)
            for (a, c), b, pc in zip(pairs, bodies, per_chunk)]


def speedup_util_batch(
        pairs: list[tuple[str, eng.VectorEngineConfig]]) -> list[dict]:
    """``speedup_batch`` plus the lane/VMU utilization the engine carry was
    already accumulating (and every caller used to drop): one row dict per
    pair with ``speedup``, ``lane_util``, ``vmu_util``.  Utilization is
    marginal over the steady-state measurement window, read from the same
    fused scan — the speedups are bitwise-identical to ``speedup_batch``.

    >>> r = speedup_util_batch(
    ...     [("blackscholes", eng.VectorEngineConfig(mvl=64, lanes=4))])[0]
    >>> sorted(r) == ['lane_util', 'speedup', 'vmu_util']
    True
    >>> 0.0 <= r["vmu_util"] <= 1.0 and r["lane_util"] > 0.1
    True
    """
    bodies = [tracegen.body_for(a, effective_mvl(a, c), c) for a, c in pairs]
    rows = eng.steady_state_time_batch(bodies, [c for _, c in pairs],
                                       with_util=True)
    return [{
        "speedup": scalar_runtime_ns(a, c) / vector_runtime_from_per_chunk(
            a, c, b, r["steady_ns"]),
        "lane_util": r["lane_util"],
        "vmu_util": r["vmu_util"],
    } for (a, c), b, r in zip(pairs, bodies, rows)]


def sweep(app_name: str, mvls=(8, 16, 32, 64, 128, 256), lanes=(1, 2, 4, 8),
          utilization: bool = False, **overrides) -> dict:
    """The paper's 24-configuration sweep (Table 10), batched.

    Cell values are speedups; with ``utilization=True`` each cell is instead
    a row dict ``{"speedup", "lane_util", "vmu_util"}`` (same speedups —
    the utilization columns ride the same fused scan)."""
    grid = [(m, l) for m in mvls for l in lanes]
    pairs = [(app_name, eng.VectorEngineConfig(mvl=m, lanes=l, **overrides))
             for m, l in grid]
    vals = speedup_util_batch(pairs) if utilization else speedup_batch(pairs)
    return dict(zip(grid, vals))


def sweep_all(apps=None, mvls=(8, 16, 32, 64, 128, 256), lanes=(1, 2, 4, 8),
              utilization: bool = False, **overrides) -> dict:
    """Full paper study — every app x the 24-config grid — in one batch."""
    apps = list(apps) if apps is not None else sorted(tracegen.APPS)
    grid = [(m, l) for m in mvls for l in lanes]
    pairs = [(a, eng.VectorEngineConfig(mvl=m, lanes=l, **overrides))
             for a in apps for m, l in grid]
    flat = speedup_util_batch(pairs) if utilization else speedup_batch(pairs)
    return {a: dict(zip(grid, flat[i * len(grid):(i + 1) * len(grid)]))
            for i, a in enumerate(apps)}


def dse_explore(space, apps=None, cache=None, warmup: int = 8,
                measure: int = 24):
    """Design-space exploration over the suite: evaluate ``apps`` (default:
    all 10) on every config of ``space``, sharded across devices and deduped
    through ``cache`` — ``repro.core.dse.explore`` with the suite's timing
    pipeline.  Returns a ``dse.DseResult``; ``.frontiers()`` gives the
    per-app Pareto frontier (runtime vs. area proxy)."""
    from repro.core import dse
    return dse.explore(space, apps=apps, cache=cache, warmup=warmup,
                       measure=measure)


def dse_best_under_budget(space, budget_kb: float, apps=None,
                          cache=None) -> dict:
    """Per-app "best config under an area budget" report: the fastest
    explored config whose ``dse.area_proxy_kb`` fits ``budget_kb``
    (``None`` when nothing fits)."""
    from repro.core import dse
    res = dse.explore(space, apps=apps, cache=cache)
    return {a: dse.best_under_budget(recs, budget_kb)
            for a, recs in res.by_app().items()}
