"""Vector IR: the trace format consumed by the engine timing model.

A trace is a struct-of-arrays (one entry per instruction, program order).
Scalar instructions are run-length compressed into ``SCALAR_BLOCK`` entries
(the paper's tables count them individually; the timing model only needs the
latency-weighted block cost).  This mirrors the paper's gem5 model boundary:
vector instructions are handed to the decoupled engine at scalar commit
(§3.1), so wrong-path effects never reach the vector engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# instruction kinds
SCALAR_BLOCK = 0   # `scalar_count` scalar instructions of class `fu_class`
VARITH = 1
VLOAD = 2
VSTORE = 3
VSLIDE = 4         # slide1up/slide1down: lane interconnect, distance 1
VREDUCE = 5        # reduction via binary operator tree across lanes
VMASK_SCALAR = 6   # vfirst.m / vpopc.m: writes a scalar register
VMOVE = 7          # whole-register moves / spill code (VL = MVL)
NOP = 8            # explicit padding entry: provably timing-neutral

KIND_NAMES = {
    SCALAR_BLOCK: "scalar", VARITH: "arith", VLOAD: "load", VSTORE: "store",
    VSLIDE: "slide", VREDUCE: "reduce", VMASK_SCALAR: "mask2s", VMOVE: "move",
    NOP: "nop",
}

# functional-unit classes (latency class of the operation)
FU_SIMPLE = 0      # add/sub/logic/compare/min/max
FU_MUL = 1         # mul / fused multiply-add
FU_DIV = 2         # div / sqrt
FU_TRANS = 3       # log / exp / cos (transcendental)
N_FU_CLASSES = 4

# memory access patterns
MEM_UNIT = 0
MEM_STRIDED = 1
MEM_INDEXED = 2


@dataclass
class Trace:
    """Struct-of-arrays instruction trace (np arrays, jnp-convertible)."""
    kind: np.ndarray           # int32 [N]
    vl: np.ndarray             # int32 [N] vector length (elements)
    fu: np.ndarray             # int32 [N] FU class
    n_src: np.ndarray          # int32 [N] vector source operands (VRF reads)
    src1: np.ndarray           # int32 [N] logical reg or -1
    src2: np.ndarray
    dst: np.ndarray            # int32 [N] logical dest reg or -1
    mem_pattern: np.ndarray    # int32 [N] MEM_* for loads/stores
    footprint_kb: np.ndarray   # f32 [N] working-set footprint (KB) of the
                               #   stream this access belongs to; miss
                               #   probabilities are derived from it by
                               #   repro.core.memory at simulation time
    scalar_count: np.ndarray   # int32 [N] for SCALAR_BLOCK
    dep_scalar: np.ndarray     # bool [N] consumes the engine's scalar result

    def __len__(self):
        return len(self.kind)

    @staticmethod
    def from_records(recs: list[dict]) -> "Trace":
        n = len(recs)
        get = lambda k, d=0: np.asarray([r.get(k, d) for r in recs])
        return Trace(
            kind=get("kind").astype(np.int32),
            vl=get("vl", 0).astype(np.int32),
            fu=get("fu", FU_SIMPLE).astype(np.int32),
            n_src=get("n_src", 2).astype(np.int32),
            src1=get("src1", -1).astype(np.int32),
            src2=get("src2", -1).astype(np.int32),
            dst=get("dst", -1).astype(np.int32),
            mem_pattern=get("mem_pattern", MEM_UNIT).astype(np.int32),
            footprint_kb=get("footprint_kb", 0.0).astype(np.float32),
            scalar_count=get("scalar_count", 0).astype(np.int32),
            dep_scalar=get("dep_scalar", False).astype(bool),
        )

    def tile(self, n: int) -> "Trace":
        """Repeat the trace n times (steady-state loop bodies)."""
        return Trace(**{k: np.tile(getattr(self, k), n)
                        for k in self.__dataclass_fields__})

    def concat(self, other: "Trace") -> "Trace":
        return Trace(**{k: np.concatenate([getattr(self, k), getattr(other, k)])
                        for k in self.__dataclass_fields__})

    def pad_to(self, n: int) -> "Trace":
        """Append NOP entries until the trace has exactly n instructions.

        NOPs take the scalar path with scalar_count=0 and dep_scalar=False, so
        they advance no clock and touch no engine resource: padding the tail
        of a trace never changes the simulated time (tests/test_batch_engine
        asserts this bitwise).
        """
        if n < len(self):
            raise ValueError(f"pad_to({n}) on trace of length {len(self)}")
        if n == len(self):
            return self
        return self.concat(nop_trace(n - len(self)))


def nop_trace(n: int) -> Trace:
    """A trace of n timing-neutral padding entries."""
    i32 = lambda v: np.full(n, v, np.int32)
    return Trace(
        kind=i32(NOP), vl=i32(0), fu=i32(FU_SIMPLE), n_src=i32(0),
        src1=i32(-1), src2=i32(-1), dst=i32(-1), mem_pattern=i32(MEM_UNIT),
        footprint_kb=np.zeros(n, np.float32),
        scalar_count=i32(0), dep_scalar=np.zeros(n, bool),
    )


def stack_traces(traces: list["Trace"], length: int | None = None) -> Trace:
    """Pad every trace to a common length and stack along a new batch axis.

    Returns a Trace whose fields are [B, L] arrays — the layout consumed by
    ``engine.simulate_batch`` (vmap over axis 0, scan over axis 1).
    """
    if length is None:
        length = max(len(t) for t in traces)
    padded = [t.pad_to(length) for t in traces]
    return Trace(**{k: np.stack([getattr(t, k) for t in padded])
                    for k in Trace.__dataclass_fields__})


def mix_counts(n: int, mix: dict) -> dict:
    """Split n arithmetic instructions into FU classes by an app mix.

    The rounding residue lands on FU_SIMPLE, so the counts always sum to n.
    """
    out = {}
    acc = 0
    classes = [FU_SIMPLE, FU_MUL, FU_DIV, FU_TRANS]
    fracs = [mix.get(c, 0.0) for c in ("simple", "mul", "div", "trans")]
    for cls, f in zip(classes, fracs):
        k = int(round(n * f))
        out[cls] = k
        acc += k
    out[FU_SIMPLE] += n - acc
    return out


def fu_sequence(n: int, mix: dict) -> list:
    """The canonical shuffled FU-class sequence for n arithmetic instructions.

    Both trace frontends draw from this one generator — the hand-coded
    ``tracegen`` bodies and the jaxpr frontend's ``chain_ops`` — so a derived
    body's FU histogram matches the hand-coded one exactly by construction.
    """
    cm = mix_counts(n, mix)
    seq = []
    for cls, k in cm.items():
        seq += [cls] * k
    rng = np.random.RandomState(0)
    rng.shuffle(seq)
    return seq


class TraceBuilder:
    """Incremental builder for instruction traces.

    The shared construction API of both trace frontends: the hand-coded
    ``tracegen`` loop bodies append records through it, and the jaxpr
    frontend (``repro.core.frontend``) emits its lowered instructions through
    the same methods — so the two paths cannot diverge on record layout.
    Methods return ``self`` for chaining; ``build()`` finalizes a ``Trace``.
    """

    def __init__(self):
        self._recs: list[dict] = []

    def __len__(self) -> int:
        return len(self._recs)

    @property
    def records(self) -> list[dict]:
        return self._recs

    def scalar(self, count, fu: int = FU_SIMPLE,
               dep_scalar: bool = False) -> "TraceBuilder":
        self._recs.append(scalar_block(count, fu=fu, dep_scalar=dep_scalar))
        return self

    def arith(self, vl, fu=FU_SIMPLE, n_src=2, src1=0, src2=1,
              dst=2) -> "TraceBuilder":
        self._recs.append(varith(vl, fu=fu, n_src=n_src, src1=src1,
                                 src2=src2, dst=dst))
        return self

    def arith_chain(self, n, mix, vl, start_reg: int = 4,
                    window: int = 16) -> "TraceBuilder":
        """n arith instructions with a rotating register dependency window —
        the hand-coded frontends' equivalent of ``frontend.chain_ops``."""
        for i, cls in enumerate(fu_sequence(n, mix)):
            self.arith(vl, fu=cls,
                       src1=start_reg + ((i + 5) % window),
                       src2=start_reg + ((i + 11) % window),
                       dst=start_reg + (i % window))
        return self

    def load(self, vl, dst=0, pattern=MEM_UNIT,
             footprint_kb=64.0) -> "TraceBuilder":
        self._recs.append(vload(vl, dst=dst, pattern=pattern,
                                footprint_kb=footprint_kb))
        return self

    def store(self, vl, src1=0, pattern=MEM_UNIT,
              footprint_kb=64.0) -> "TraceBuilder":
        self._recs.append(vstore(vl, src1=src1, pattern=pattern,
                                 footprint_kb=footprint_kb))
        return self

    def slide(self, vl, src1=0, dst=1) -> "TraceBuilder":
        self._recs.append(vslide(vl, src1=src1, dst=dst))
        return self

    def reduce(self, vl, src1=0, dst=1, fu=FU_SIMPLE) -> "TraceBuilder":
        self._recs.append(vreduce(vl, src1=src1, dst=dst, fu=fu))
        return self

    def mask_to_scalar(self, vl, src1=0) -> "TraceBuilder":
        self._recs.append(vmask_scalar(vl, src1=src1))
        return self

    def move(self, vl, src1=0, dst=1) -> "TraceBuilder":
        self._recs.append(vmove(vl, src1=src1, dst=dst))
        return self

    def raw(self, rec: dict) -> "TraceBuilder":
        self._recs.append(dict(rec))
        return self

    def extend(self, recs) -> "TraceBuilder":
        self._recs.extend(recs)
        return self

    def build(self) -> Trace:
        return Trace.from_records(self._recs)


def trace_fingerprint(trace: Trace) -> str:
    """Content hash of a trace (all fields, program order) — the trace half
    of the DSE result-cache key (``repro.core.dse``).  Two traces share a
    fingerprint iff every instruction field is bitwise identical, so cached
    timings can never be served to a different workload."""
    import hashlib
    h = hashlib.sha1()
    for name in Trace.__dataclass_fields__:
        a = np.ascontiguousarray(getattr(trace, name))
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


def trace_records(trace: Trace) -> list[dict]:
    """The inverse of ``Trace.from_records``: one plain dict per instruction.

    Every field is materialized as a Python scalar (no numpy types), so
    ``Trace.from_records(trace_records(t))`` reproduces ``t`` bitwise —
    the record view the RVV code generator walks when spelling a trace
    back out as assembly.
    """
    return [
        dict(kind=int(trace.kind[i]), vl=int(trace.vl[i]),
             fu=int(trace.fu[i]), n_src=int(trace.n_src[i]),
             src1=int(trace.src1[i]), src2=int(trace.src2[i]),
             dst=int(trace.dst[i]), mem_pattern=int(trace.mem_pattern[i]),
             footprint_kb=float(trace.footprint_kb[i]),
             scalar_count=int(trace.scalar_count[i]),
             dep_scalar=bool(trace.dep_scalar[i]))
        for i in range(len(trace))
    ]


def trace_registers(trace: Trace) -> int:
    """Number of distinct logical vector registers a trace touches — the
    register-pressure figure the cross-validation contract compares."""
    regs = np.concatenate([trace.src1, trace.src2, trace.dst])
    return int(np.unique(regs[regs >= 0]).size)


def kind_histogram(trace: Trace) -> np.ndarray:
    """Instruction-kind histogram (len 9, indexed by the KIND constants)."""
    return np.bincount(trace.kind, minlength=NOP + 1)


N_ARCH_REGS = 32   # architectural vector registers (the scoreboard size)


def validate_trace(trace: Trace, mvl: int | None = None,
                   predefined=()) -> list[str]:
    """Structural invariants a decoder-produced trace must satisfy.

    Returns a list of problem strings (empty == valid):

    * every register index in ``[0, N_ARCH_REGS)``,
    * ``vl <= mvl`` on every vector entry (when ``mvl`` is given),
    * no vector source register read before its first write — registers in
      ``predefined`` (e.g. a decoded kernel's prologue definitions) count as
      written at entry.

    The RVV frontend's fuzz tier (``tests/test_rvv.py``) holds every
    successfully decoded stream to these; the hand-coded ``tracegen`` bodies
    intentionally do *not* satisfy the dangling-source rule (their windows
    model registers carried across chunk iterations), so this is a decoder
    contract, not a global ``Trace`` one.
    """
    problems: list[str] = []
    regs = np.stack([trace.src1, trace.src2, trace.dst])
    bad = (regs >= N_ARCH_REGS) | ((regs < 0) & (regs != -1))
    if bad.any():
        problems.append(f"register index out of [0,{N_ARCH_REGS}): "
                        f"{sorted(set(regs[bad].tolist()))}")
    vec = trace.kind != SCALAR_BLOCK
    if mvl is not None and (trace.vl[vec] > mvl).any():
        problems.append(
            f"vl exceeds mvl={mvl}: max {int(trace.vl[vec].max())}")
    written = set(int(r) for r in predefined)
    for i in range(len(trace)):
        if not vec[i]:
            continue
        srcs = [int(trace.src1[i]), int(trace.src2[i])]
        for s in srcs[:max(int(trace.n_src[i]), 0)]:
            if s >= 0 and s not in written:
                problems.append(f"instr {i}: src v{s} read before first write")
        if int(trace.dst[i]) >= 0:
            written.add(int(trace.dst[i]))
    return problems


def scalar_block(count: int, fu: int = FU_SIMPLE, dep_scalar: bool = False) -> dict:
    return dict(kind=SCALAR_BLOCK, scalar_count=int(round(count)), fu=fu,
                dep_scalar=dep_scalar)


def varith(vl, fu=FU_SIMPLE, n_src=2, src1=0, src2=1, dst=2) -> dict:
    return dict(kind=VARITH, vl=vl, fu=fu, n_src=n_src, src1=src1, src2=src2, dst=dst)


def vload(vl, dst=0, pattern=MEM_UNIT, footprint_kb=64.0) -> dict:
    return dict(kind=VLOAD, vl=vl, dst=dst, mem_pattern=pattern, n_src=0,
                footprint_kb=footprint_kb)


def vstore(vl, src1=0, pattern=MEM_UNIT, footprint_kb=64.0) -> dict:
    return dict(kind=VSTORE, vl=vl, src1=src1, dst=-1, mem_pattern=pattern,
                n_src=1, footprint_kb=footprint_kb)


def vslide(vl, src1=0, dst=1) -> dict:
    return dict(kind=VSLIDE, vl=vl, src1=src1, dst=dst, n_src=1)


def vreduce(vl, src1=0, dst=1, fu=FU_SIMPLE) -> dict:
    return dict(kind=VREDUCE, vl=vl, src1=src1, dst=dst, n_src=1, fu=fu)


def vmask_scalar(vl, src1=0) -> dict:
    return dict(kind=VMASK_SCALAR, vl=vl, src1=src1, dst=-1, n_src=1)


def vmove(vl, src1=0, dst=1) -> dict:
    return dict(kind=VMOVE, vl=vl, src1=src1, dst=dst, n_src=1)


def nop() -> dict:
    return dict(kind=NOP, n_src=0, src1=-1, src2=-1, dst=-1)
