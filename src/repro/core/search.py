"""Surrogate-guided design search: million-point spaces, exact-only answers.

``repro.core.dse`` explores exhaustively — every (app, config) cell hits the
engine (or its cache).  That tops out around ``SPACE_FULL`` (1536 configs).
This module searches spaces orders of magnitude larger (``SPACE_HUGE``,
1,244,160 configs; anything a mixed-radix ``DesignSpace`` can address) by
splitting the work:

1. **Score** every candidate with the learned surrogate
   (``repro.core.surrogate.SpaceScorer``) — microseconds per point, jitted
   batches.  Spaces up to ``exhaustive_limit`` are scored wholesale; larger
   ones run a deterministic evolutionary loop (random proposals + one-knob
   mutations of the current elite, per-app near-frontier archives).
2. **Prune** to the predicted near-Pareto band (:func:`_survivors`):
   candidates whose predicted runtime is within ``1+eps`` of the best
   prediction at their area or below, capped at ``max_resim_per_app``.
3. **Re-simulate the survivors exactly** through ``dse.explore`` and the
   shared ``ResultCache`` — the SAME dispatch/keying path the exhaustive
   sweeps use — and take the Pareto frontier of those *exact* records.

The exactness guarantee is structural: frontiers are built from
``dse.DseRecord``s produced by ``dse.explore``, never from predictions — a
surrogate number cannot appear in a reported result, only fail to nominate a
candidate (which costs recall, measured by :func:`frontier_recall`, never
correctness).  Determinism: same (space, apps, trained model, seed) ->
bitwise-identical frontiers (``frontier_fingerprint``); the ``--smoke`` CLI
is the CI gate for both properties.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from repro.core import dse
from repro.core import surrogate as surro


# --------------------------------------------------------------------------
# results
# --------------------------------------------------------------------------

@dataclass
class SearchResult:
    """A surrogate-guided search: exact records + frontiers + accounting.

    ``records``/``frontiers`` hold ``dse.DseRecord``s from the exact engine
    path only.  ``stats`` carries the search economics: candidates scored,
    survivors re-simulated, cache behavior of the re-simulation.
    """
    space: str
    apps: tuple
    records: dict          # app -> [DseRecord], exact, resim order
    frontiers: dict        # app -> [DseRecord], Pareto of `records[app]`
    stats: dict


def frontier_fingerprint(res: SearchResult) -> str:
    """Hash of every frontier's exact float values — same recipe as
    ``dse._frontier_fingerprint``, the bitwise-repeatability contract."""
    h = hashlib.sha1()
    for app in res.apps:
        for r in res.frontiers[app]:
            h.update(f"{app}|{r.label}|{r.runtime_ns!r}|{r.area_kb!r}"
                     .encode())
    return h.hexdigest()[:16]


def frontier_recall(found, truth) -> float:
    """Fraction of ``truth`` frontier points weakly dominated by some
    ``found`` record (<= in both runtime and area).  The acceptance metric:
    1.0 means the search recovered (or beat) every exhaustive-truth point.

    >>> from types import SimpleNamespace as R
    >>> truth = [R(runtime_ns=10.0, area_kb=5.0), R(runtime_ns=20.0, area_kb=1.0)]
    >>> frontier_recall([R(runtime_ns=10.0, area_kb=5.0)], truth)
    0.5
    >>> frontier_recall([R(runtime_ns=9.0, area_kb=1.0)], truth)
    1.0
    """
    if not truth:
        return 1.0
    if not found:
        return 0.0
    fr = np.asarray([f.runtime_ns for f in found])
    fa = np.asarray([f.area_kb for f in found])
    hit = sum(1 for t in truth
              if bool(np.any((fr <= t.runtime_ns) & (fa <= t.area_kb))))
    return hit / len(truth)


# --------------------------------------------------------------------------
# predicted near-frontier selection
# --------------------------------------------------------------------------

def _survivors(idx, pred, area, eps: float, cap: int,
               depth: int = 3) -> np.ndarray:
    """Indices (ascending) of candidates on or near the *predicted* Pareto
    frontier: sort by (area, pred), take the running best prediction at or
    below each area, and keep points within ``1+eps`` of it.

    When more than ``cap`` qualify, the band is split into ``cap // depth``
    contiguous strata along the area-sorted order and each stratum keeps its
    ``depth`` closest-to-frontier candidates (smallest pred/best ratio, ties
    by flat index).  Two deliberate properties:

    * *Coverage* — stratifying, rather than globally keeping the smallest
      ratios, spreads survivors across the whole area range; a global
      top-``cap`` collapses onto whichever region is densest and leaves the
      rest of the frontier unexplored.
    * *Redundancy* — ``depth`` per-stratum picks, not one: the surrogate's
      few-percent noise regularly puts a slightly-slower config a hair below
      the true best, and the second/third nominee is what lets the exact
      re-simulation recover the real frontier point.

    Pure numpy, deterministic.

    >>> idx = np.array([0, 1, 2, 3])
    >>> pred = np.array([10.0, 11.0, 30.0, 5.0])
    >>> area = np.array([1.0, 1.0, 2.0, 3.0])
    >>> _survivors(idx, pred, area, eps=0.15, cap=10).tolist()
    [0, 1, 3]
    >>> _survivors(idx, pred, area, eps=0.15, cap=2).tolist()  # ratio ties
    [0, 3]
    """
    idx = np.asarray(idx)
    pred = np.asarray(pred, np.float64)
    area = np.asarray(area, np.float64)
    order = np.lexsort((idx, pred, area))        # area asc, then pred, then id
    best = np.minimum.accumulate(pred[order])    # best pred at <= this area
    ratio = pred[order] / best
    band = np.nonzero(ratio <= 1.0 + eps)[0]
    if len(band) > cap:
        take = min(depth, cap)
        picks = []
        for stratum in np.array_split(band, max(1, cap // take)):
            if len(stratum):
                k = np.lexsort((idx[order][stratum], ratio[stratum]))
                picks.extend(stratum[k[:take]])
        band = np.sort(np.asarray(picks))
    return np.sort(idx[order][band])


# --------------------------------------------------------------------------
# candidate generation (the > exhaustive_limit path)
# --------------------------------------------------------------------------

def _decode(idx, radices) -> np.ndarray:
    """Flat indices -> axis digits, mixed radix, last axis fastest (the
    ``DesignSpace.config_at`` rule)."""
    digits = np.empty((len(idx), len(radices)), np.int64)
    rem = np.asarray(idx, np.int64).copy()
    for a in range(len(radices) - 1, -1, -1):
        rem, digits[:, a] = np.divmod(rem, radices[a])
    return digits


def _encode(digits, radices) -> np.ndarray:
    out = np.zeros(len(digits), np.int64)
    for a in range(len(radices)):
        out = out * radices[a] + digits[:, a]
    return out


def _mutate(rng, elite_idx, radices, n: int) -> np.ndarray:
    """``n`` one-knob mutations of elites: pick an elite, pick an axis,
    replace that digit with a uniform choice."""
    if len(elite_idx) == 0 or n <= 0:
        return np.empty(0, np.int64)
    base = elite_idx[rng.randint(len(elite_idx), size=n)]
    digits = _decode(base, radices)
    axis = rng.randint(len(radices), size=n)
    new = np.array([rng.randint(radices[a]) for a in axis], np.int64)
    digits[np.arange(n), axis] = new
    return _encode(digits, radices)


def _neighbors(idx, radices) -> np.ndarray:
    """The complete one-knob neighborhood of ``idx``: every config reachable
    by changing exactly one axis digit.  Deterministic (sorted, unique).

    >>> _neighbors(np.array([0]), [2, 3]).tolist()   # (0,0) -> one-knob flips
    [1, 2, 3]
    """
    idx = np.asarray(idx, np.int64)
    if len(idx) == 0:
        return np.empty(0, np.int64)
    digits = _decode(idx, radices)
    out = []
    for a, r in enumerate(radices):
        for v in range(r):
            mask = digits[:, a] != v
            if mask.any():
                d = digits[mask].copy()
                d[:, a] = v
                out.append(_encode(d, radices))
    return np.unique(np.concatenate(out)) if out else np.empty(0, np.int64)


# --------------------------------------------------------------------------
# the search
# --------------------------------------------------------------------------

def search(space, apps, model, cache: dse.ResultCache | None = None,
           seed: int = 0, eps: float = 0.2, max_resim_per_app: int = 480,
           refine_rounds: int = 2, exhaustive_limit: int = 1 << 21,
           rounds: int = 8, pop: int = 1 << 16, warmup: int = 8,
           measure: int = 24) -> SearchResult:
    """Surrogate-guided exploration of ``space`` for ``apps``.

    Spaces up to ``exhaustive_limit`` points are surrogate-scored wholesale
    (``SPACE_HUGE``'s 1.24M points is a handful of jitted dispatches per
    app); larger spaces run ``rounds`` of a deterministic evolutionary loop
    (``pop`` fresh uniform proposals + one-knob mutations of the per-app
    near-frontier archive each round).  Either way, at most
    ``max_resim_per_app`` predicted near-Pareto survivors per app are then
    evaluated EXACTLY via ``dse.explore`` through ``cache``, followed by
    ``refine_rounds`` of exact one-knob local search around the running
    exact frontier (the surrogate nominates the region, refinement walks
    the last knobs); the reported frontier is the Pareto set of those
    exact records.

    Deterministic in (space, apps, model parameters, seed): repeat calls
    produce bitwise-identical frontiers, simulated or cached.
    """
    import time as _time

    from repro.core import telemetry
    apps = tuple(apps)
    cache = cache if cache is not None else dse.ResultCache()
    total = space.size()
    radices = [len(c) for _, c in space.axes]
    scorers = {app: surro.SpaceScorer(model, space, app) for app in apps}
    _t0 = _time.perf_counter()

    per_app_idx: dict[str, np.ndarray] = {}
    n_scored = 0
    if total <= exhaustive_limit:
        all_idx = np.arange(total, dtype=np.int64)
        for app in apps:
            pred, area = scorers[app].score(all_idx)
            n_scored += total
            per_app_idx[app] = _survivors(all_idx, pred, area, eps,
                                          max_resim_per_app)
        mode = "exhaustive-score"
    else:
        rng = np.random.RandomState(seed)
        seen = np.empty(0, np.int64)
        # archives: per-app (idx, pred, area) of the near-frontier so far
        arch = {app: (np.empty(0, np.int64), np.empty(0), np.empty(0))
                for app in apps}
        arch_cap = max(4 * max_resim_per_app, 64)
        for _ in range(rounds):
            fresh = rng.randint(total, size=pop).astype(np.int64)
            muts = [_mutate(rng, arch[app][0], radices, pop // 4)
                    for app in apps]
            cand = np.unique(np.concatenate([fresh, *muts]))
            cand = np.setdiff1d(cand, seen, assume_unique=True)
            if len(cand) == 0:
                continue
            seen = np.union1d(seen, cand)
            for app in apps:
                pred, area = scorers[app].score(cand)
                n_scored += len(cand)
                ai, ap, aa = arch[app]
                ci = np.concatenate([ai, cand])
                cp = np.concatenate([ap, pred.astype(np.float64)])
                ca = np.concatenate([aa, area.astype(np.float64)])
                keep = _survivors(ci, cp, ca, eps, arch_cap)
                # re-gather by flat index (ci unique: archive ∩ cand = ∅)
                lut = {int(i): k for k, i in enumerate(ci)}
                sel = np.asarray([lut[int(i)] for i in keep], np.int64)
                arch[app] = (ci[sel], cp[sel], ca[sel])
        for app in apps:
            ai, ap, aa = arch[app]
            per_app_idx[app] = _survivors(ai, ap, aa, eps, max_resim_per_app)
        mode = "evolutionary"

    # Exact re-simulation of the survivors — the only numbers we report —
    # followed by `refine_rounds` of exact local search: the complete
    # one-knob neighborhood of the current exact frontier is re-simulated
    # and the frontier recomputed.  The surrogate nominates the region;
    # refinement walks the last knob or two to the true local optimum,
    # closing the few-percent gaps that surrogate noise (winner's curse:
    # the predicted-best of thousands of near-ties is the most
    # *under*-predicted, not the fastest) leaves behind.
    _t_score = _time.perf_counter()
    records: dict[str, list] = {}
    frontiers: dict[str, list] = {}
    resim_stats: dict[str, dict] = {}
    _t_resim = _t_refine = 0.0
    for app in apps:
        _ta = _time.perf_counter()
        seen_idx = np.unique(per_app_idx[app].astype(np.int64))
        cfgs = [space.config_at(int(i)) for i in seen_idx]
        idx_of = {c: int(i) for c, i in zip(cfgs, seen_idx)}
        res = dse.explore(cfgs, apps=(app,), cache=cache,
                          warmup=warmup, measure=measure)
        recs = list(res.records)
        simulated = res.stats["simulated"]
        frontier = dse.pareto_frontier(recs)
        refined = 0
        _tb = _time.perf_counter()
        _t_resim += _tb - _ta
        for _ in range(refine_rounds):
            f_idx = np.asarray(sorted(idx_of[r.cfg] for r in frontier),
                               np.int64)
            nbrs = np.setdiff1d(_neighbors(f_idx, radices), seen_idx,
                                assume_unique=True)
            if len(nbrs) == 0:
                break
            ncfgs = [space.config_at(int(i)) for i in nbrs]
            idx_of.update({c: int(i) for c, i in zip(ncfgs, nbrs)})
            r2 = dse.explore(ncfgs, apps=(app,), cache=cache,
                             warmup=warmup, measure=measure)
            recs.extend(r2.records)
            simulated += r2.stats["simulated"]
            refined += len(nbrs)
            seen_idx = np.union1d(seen_idx, nbrs)
            new_frontier = dse.pareto_frontier(recs)
            converged = ([(r.label, r.runtime_ns) for r in new_frontier]
                         == [(r.label, r.runtime_ns) for r in frontier])
            frontier = new_frontier
            if converged:
                break
        records[app] = recs
        frontiers[app] = frontier
        resim_stats[app] = {"resim": int(len(seen_idx)), "refined": refined,
                            "simulated": simulated}
        _t_refine += _time.perf_counter() - _tb
    phases = [
        telemetry.snapshot_row("search.phase", phase="score",
                               wall_s=_t_score - _t0, mode=mode,
                               n_scored=n_scored),
        telemetry.snapshot_row("search.phase", phase="resim",
                               wall_s=_t_resim,
                               simulated=sum(r["simulated"]
                                             for r in resim_stats.values())),
        telemetry.snapshot_row("search.phase", phase="refine",
                               wall_s=_t_refine,
                               refined=sum(r["refined"]
                                           for r in resim_stats.values())),
    ]
    stats = {
        "mode": mode,
        "space_size": total,
        "n_scored": n_scored,
        "eps": eps,
        "max_resim_per_app": max_resim_per_app,
        "refine_rounds": refine_rounds,
        "resim": resim_stats,
        "phases": phases,
    }
    return SearchResult(space=space.name, apps=apps, records=records,
                        frontiers=frontiers, stats=stats)


# --------------------------------------------------------------------------
# CLI / CI smoke gate
# --------------------------------------------------------------------------

def _verify_exact(res: SearchResult, cache: dse.ResultCache,
                  warmup: int = 8, measure: int = 24) -> int:
    """Assert every frontier record is backed by an exact engine result in
    ``cache`` and that its runtime re-derives bitwise from the cached
    steady-state time.  Returns the number of points checked."""
    from repro.core import suite
    checked = 0
    for app in res.apps:
        for r in res.frontiers[app]:
            body, key = dse.cell_key(app, r.cfg, warmup, measure)
            steady = cache._mem.get(key)
            assert steady is not None, f"frontier point not in cache: {key}"
            assert steady == r.steady_ns, (app, r.label)
            rt = suite.vector_runtime_from_per_chunk(app, r.cfg, body, steady)
            assert rt == r.runtime_ns, (app, r.label)
            checked += 1
    return checked


def main(argv=None) -> int:
    import argparse
    import time
    from repro.configs import vector_engine as vcfg
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--space", default="10k", choices=("10k", "huge"))
    ap.add_argument("--train-space", default="smoke",
                    choices=("smoke", "quick", "full"))
    ap.add_argument("--apps", default="blackscholes,canneal")
    ap.add_argument("--cache", default=None, help="JSONL cache path")
    ap.add_argument("--steps", type=int, default=800)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: train on a 64-point explore, search the "
                         "18k-point space, assert every frontier point is "
                         "exact-verified and repeat runs (both scoring "
                         "modes) are bitwise-identical")
    args = ap.parse_args(argv)
    apps = tuple(args.apps.split(","))
    train_space = {"smoke": vcfg.SPACE_SMOKE, "quick": vcfg.SPACE_QUICK,
                   "full": vcfg.SPACE_FULL}[args.train_space]
    space = {"10k": vcfg.SPACE_10K, "huge": vcfg.SPACE_HUGE}[args.space]

    cache = dse.ResultCache(args.cache)
    t0 = time.perf_counter()
    dse.explore(train_space, apps, cache=cache)
    rows = cache.export_training_rows(apps, train_space)
    t_label = time.perf_counter() - t0
    t0 = time.perf_counter()
    model = surro.fit(rows, steps=args.steps, seed=args.seed)
    t_fit = time.perf_counter() - t0
    print(f"train: {len(rows)} rows from {train_space.name} in {t_label:.2f}s"
          f", fit {t_fit:.2f}s (final_loss={model.meta['final_loss']:.2e})")

    t0 = time.perf_counter()
    res = search(space, apps, model, cache=cache, seed=args.seed)
    t_search = time.perf_counter() - t0
    n = _verify_exact(res, cache)
    print(f"search: {space.name} ({res.stats['space_size']:,} configs) "
          f"mode={res.stats['mode']} scored={res.stats['n_scored']:,} "
          f"in {t_search:.2f}s; {n} frontier points exact-verified")
    for app in res.apps:
        rs = res.stats["resim"][app]
        print(f"  {app:16s} frontier={len(res.frontiers[app]):3d} pts  "
              f"resim={rs['resim']} (simulated={rs['simulated']})")
    card = surro.scorecard(model, rows)
    print(f"  fit-set scorecard: p50={card['rel_err_p50']:.1%} "
          f"p90={card['rel_err_p90']:.1%} max={card['rel_err_max']:.1%} "
          f"spearman={card['spearman_all']:.4f}")
    if not args.smoke:
        return 0

    fp1 = frontier_fingerprint(res)
    res2 = search(space, apps, model, cache=cache, seed=args.seed)
    fp2 = frontier_fingerprint(res2)
    _verify_exact(res2, cache)
    # the evolutionary path must hold the same determinism contract
    evo = [search(space, apps, model, cache=cache, seed=args.seed,
                  exhaustive_limit=0, rounds=3, pop=4096) for _ in range(2)]
    for e in evo:
        _verify_exact(e, cache)
    fpe1, fpe2 = (frontier_fingerprint(e) for e in evo)
    ok = fp1 == fp2 and fpe1 == fpe2
    print(f"repeat: exhaustive {'bitwise-identical' if fp1 == fp2 else 'DIVERGED'}"
          f" ({fp1}); evolutionary "
          f"{'bitwise-identical' if fpe1 == fpe2 else 'DIVERGED'} ({fpe1}) "
          f"-> {'ok' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    from repro.core import search as _canonical
    raise SystemExit(_canonical.main())
