"""Instruction-level characterization (paper §4, Tables 3-9) and VAO speedups.

Definitions (paper §4.1.1):
  %vectorization = vector_ops / (scalar_instrs + vector_ops)
  average VL     = vector_ops / total_vector_instrs
  VAO speedup    = scalar_code_total / (scalar_instrs + vector_ops)
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.tracegen import APPS, Counts

# The paper's published table cells used as regression goldens:
# app -> mvl -> (total_instr, scalar, vec_mem, vec_arith(+manip+moves), vec_ops)
PAPER_TABLES = {
    "blackscholes": {  # Table 3
        8: (727_119_128, 484_635_928, 22_118_400, 220_364_800, 1_939_865_600),
        64: (342_504_727, 312_194_327, 2_764_800, 27_545_600, 1_939_865_600),
        256: (298_856_749, 291_279_149, 691_200, 6_886_400, 1_939_865_600),
    },
    "canneal": {  # Table 4
        8: (3_722_402_159, 3_368_424_160, 59_887_894, 294_090_105, 2_450_191_462),
        16: (3_490_359_558, 3_218_719_265, 37_432_156, 234_208_137, 3_102_641_472),
        32: (3_488_680_211, 3_217_635_854, 37_269_628, 233_774_729, 4_078_370_559),
        64: (3_488_680_211, 3_217_635_854, 37_269_628, 233_774_729, 6_030_736_943),
        128: (3_488_680_211, 3_217_635_854, 37_269_628, 233_774_729, 9_926_999_575),
        256: (3_488_680_211, 3_217_635_854, 37_269_628, 233_774_729, 17_727_994_975),
    },
    "jacobi-2d": {  # Table 5 (arith column = arith + elem-manip)
        8: (1_665_765_868, 1_275_617_868, 65_280_000, 324_868_000, 3_121_184_000),
        64: (328_373_875, 279_601_875, 8_160_000, 40_612_000, 3_121_408_000),
        256: (185_081_872, 172_885_872, 2_040_000, 10_156_000, 3_122_176_000),
    },
    "particlefilter": {  # Table 6
        8: (4_993_215_636, 3_446_128_079, 1_607_712, 1_545_479_845, 12_376_700_456),
        64: (1_617_632_096, 1_423_641_027, 200_992, 193_790_077, 12_415_428_416),
        256: (1_260_531_622, 1_211_546_181, 50_272, 48_935_169, 12_540_272_896),
    },
    "pathfinder": {  # Table 7 (arith column = arith + elem-manip)
        8: (1_337_948_580, 1_037_138_340, 100_270_080, 200_540_160, 2_406_481_920),
        64: (402_094_500, 364_493_220, 12_533_760, 25_067_520, 2_406_481_920),
        256: (301_824_392, 292_424_072, 3_133_440, 6_266_880, 2_406_481_920),
    },
    "streamcluster": {  # Table 8
        8: (6_349_730_434, 4_325_602_994, 952_530_560, 1_071_596_880, 16_193_019_520),
        64: (2_599_142_070, 2_241_943_122, 119_066_316, 238_132_632, 22_860_732_672),
        128: (2_331_242_835, 2_093_110_203, 59_533_158, 178_599_474, 30_480_976_896),
    },
    "swaptions": {  # Table 9
        8: (6_337_441_159, 4_173_151_623, 370_323_456, 1_793_966_080, 17_314_316_288),
        64: (1_022_467_455, 751_931_263, 46_290_432, 224_245_760, 17_314_316_288),
        256: (456_078_412, 388_444_364, 11_572_608, 56_061_440, 17_314_316_288),
    },
}

# VAO speedups quoted in §4.1.x (at MVL=8 unless noted)
PAPER_VAO = {
    "blackscholes": 1.78,
    "canneal": 0.90,
    "jacobi-2d": 1.09,
    "particlefilter": 1.27,
    "pathfinder": 1.8,
    "streamcluster": 1.75,
    "swaptions": 1.24,
}


@dataclass
class Characterization:
    app: str
    mvl: int
    counts: Counts

    @property
    def pct_vectorization(self) -> float:
        c = self.counts
        return c.vector_ops / (c.scalar_instrs + c.vector_ops)

    @property
    def avg_vl(self) -> float:
        c = self.counts
        return c.vector_ops / max(c.total_vector, 1)

    @property
    def vao_speedup(self) -> float:
        c = self.counts
        return c.scalar_code_total / (c.scalar_instrs + c.vector_ops)

    def row(self) -> dict:
        c = self.counts
        return {
            "app": self.app, "mvl": self.mvl,
            "total_instructions": c.total_instrs,
            "scalar_instructions": c.scalar_instrs,
            "vector_memory_instructions": c.vector_mem,
            "vector_arith_instructions": c.vector_arith + c.vector_manip,
            "total_vector_instructions": c.total_vector,
            "vector_operations": c.vector_ops,
            "pct_vectorization": self.pct_vectorization,
            "average_vl": self.avg_vl,
            "vao_speedup": self.vao_speedup,
        }


def characterize(app: str, mvl: int) -> Characterization:
    return Characterization(app, mvl, APPS[app].counts(mvl))


def table(app: str, mvls=(8, 16, 32, 64, 128, 256)) -> list[dict]:
    return [characterize(app, m).row() for m in mvls]


def compare_to_paper(app: str) -> list[dict]:
    """Model-vs-published relative errors for every golden cell."""
    out = []
    for mvl, (tot, sc, mem, arith, ops) in PAPER_TABLES[app].items():
        c = characterize(app, mvl).counts
        def err(model, paper):
            return abs(model - paper) / paper
        out.append({
            "app": app, "mvl": mvl,
            "err_total": err(c.total_instrs, tot),
            "err_scalar": err(c.scalar_instrs, sc),
            "err_mem": err(c.vector_mem, mem),
            "err_arith": err(c.vector_arith + c.vector_manip, arith),
            "err_ops": err(c.vector_ops, ops),
        })
    return out
