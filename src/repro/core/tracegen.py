"""RiVec suite models: instruction-count closed forms + timing trace bodies.

Every application encodes two things:

1. ``counts(mvl)`` — a closed-form instruction-count model whose constants are
   FITTED TO THE PAPER'S PUBLISHED TABLES (3-9).  Each constant's provenance
   is derived in comments; ``tests/test_characterize.py`` asserts the model
   reproduces every published table cell (<=1% dense apps, <=5% canneal).

2. ``body(mvl)`` — a representative loop-body trace (isa.Trace) for the
   cycle-level engine.  Per-chunk scalar overhead and the arithmetic class mix
   (simple/mul/div/transcendental) drive the *timing* reproduction of §5.
   Memory accesses carry per-stream working-set *footprints* (KB, derived
   from the published input sets where possible); the analytic hierarchy in
   ``repro.core.memory`` turns footprint x pattern x cache geometry into
   miss behavior at simulation time, so no app hard-codes a miss rate.

The large input set is modeled throughout (as in the paper's study).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

from repro.core import frontend as fe
from repro.core import isa
from repro.core.isa import (FU_DIV, FU_MUL, FU_SIMPLE, FU_TRANS, MEM_INDEXED,
                            MEM_UNIT, Trace, scalar_block, varith, vload,
                            vmask_scalar, vmove, vreduce, vslide, vstore)

import jax.numpy as jnp


@dataclass
class Counts:
    """One MVL configuration's instruction-level characterization."""
    scalar_code_total: float       # scalar-version instructions (ROI)
    scalar_instrs: float           # remaining scalar instrs, vectorized code
    vector_mem: float
    vector_arith: float
    vector_manip: float = 0.0      # slides / element manipulation
    vector_ops: float = 0.0        # element operations performed

    @property
    def total_vector(self):
        return self.vector_mem + self.vector_arith + self.vector_manip

    @property
    def total_instrs(self):
        return self.scalar_instrs + self.total_vector


@dataclass(frozen=True)
class ScalarProfile:
    """Per-app scalar-code profile driving the event-based scalar-pipeline
    baseline (``repro.core.scalar_pipeline``), replacing the retired
    ``SCALAR_BASELINE_MULT`` magic multipliers.

    ``branch_frac``/``load_frac`` are dynamic-instruction fractions of the
    scalar-version ROI; ``raw_frac`` is the dependency density (probability
    an instruction stalls on an in-flight producer's remaining latency);
    ``fusible_frac`` is the fraction of simple-class instructions leading a
    fusible pair (macro-op fusion, off by default).  ``mem_stall_cyc`` — the
    average extra scalar-core cycles per load beyond the pipelined L1 hit —
    is the ONE per-app fitted parameter (``benchmarks/calibrate.py``),
    bounded to the physical range [0, 40].  ``roi_instr_fraction`` is a
    named published-count correction: the fraction of the app's published
    scalar-instruction total that falls inside the published timing ROI
    (1.0 for every app except particlefilter — see docs/calibration.md).
    """
    branch_frac: float
    branch_miss_rate: float
    load_frac: float
    raw_frac: float
    fusible_frac: float
    mem_stall_cyc: float
    roi_instr_fraction: float = 1.0


@dataclass
class App:
    name: str
    counts: Callable[[int], Counts]
    body: Callable[[int, "object"], Trace]   # (mvl, cfg) -> one-chunk trace
    chunks: Callable[[int], float]           # loop bodies executed at this MVL
    mix: dict                                # arith class mix fractions
    init_scalar: float = 0.0                 # non-ROI init instructions
    max_vl: int = 10 ** 9                    # app's largest requested VL
    notes: str = ""
    # jaxpr-frontend chunk spec: (mvl, cfg) -> list of frontend segments.
    # For the RiVec apps it is cross-validated against `body` (same kind/FU/
    # pattern mix, same element and scalar work, steady-state time within
    # frontend.TIME_RTOL); for frontend-only workloads it IS the body.
    kernel: Callable[[int, "object"], list] = None
    # RVV assembly corpus entry (filename under src/repro/asm): the third
    # trace source, decoded by repro.core.rvv and cross-validated against
    # `body` exactly like `kernel` (python -m repro.core.rvv --check-all)
    asm: str = None


def _arith_seq(n, mix, vl, start_reg=4):
    """n vector arith instructions with a rotating register dependency chain
    (the canonical ``isa.fu_sequence`` order, shared with the jaxpr
    frontend's ``chain_ops``)."""
    return isa.TraceBuilder().arith_chain(n, mix, vl, start_reg).records


# ===========================================================================
# Blackscholes (Table 3).  PARSEC large: 65,536 options x 100 runs =
# 6,553,600 option evaluations.  Derivation from the published table:
#   mem elems / option  = 22,118,400 * 8 / 6,553,600 = 27.0
#   arith elems / option = 220,364,800 * 8 / 6,553,600 = 269.0
#   vector_ops = 296 * options = 1,939,865,600 (matches, all MVLs)
#   scalar(mvl) = s0 + s1 * chunks, fit on (MVL=8, MVL=256):
#     s1 = (484,635,928-291,279,149)/(819,200-25,600) = 243.65
#     s0 = 291,279,149 - 25,600*243.65 = 285,041,709
#   (predicts 310.0M @MVL=64 vs published 312.2M: 0.7%)
# ===========================================================================

_BS_UNITS = 6_553_600
_BS_OPTIONS = 65_536                   # unique options (x 100 runs = UNITS)
_BS_MEM_PER = 27
_BS_ARITH_PER = 269
_BS_S1 = 243.65
_BS_S0 = 285_041_709
_BS_MIX = {"simple": 0.58, "mul": 0.36, "div": 0.04, "trans": 0.02}
# memory streams: the option arrays (27 doubles/option) are re-swept on each
# of the 100 runs, so the reuse distance is the full option data set
_BS_FOOTPRINT_KB = _BS_OPTIONS * _BS_MEM_PER * 8 / 1024   # ~13.8 MB


def _bs_counts(mvl):
    chunks = _BS_UNITS / mvl
    return Counts(
        scalar_code_total=4_316_765_131,
        scalar_instrs=_BS_S0 + _BS_S1 * chunks,
        vector_mem=_BS_MEM_PER * chunks,
        vector_arith=_BS_ARITH_PER * chunks,
        vector_ops=296 * _BS_UNITS,
    )


def _bs_body(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    recs = [scalar_block(_BS_S1)]
    for i in range(_BS_MEM_PER - 5):
        recs.append(vload(vl, dst=i % 4, footprint_kb=_BS_FOOTPRINT_KB))
    recs += _arith_seq(_BS_ARITH_PER, _BS_MIX, vl)
    for i in range(5):
        recs.append(vstore(vl, src1=4 + i, footprint_kb=_BS_FOOTPRINT_KB))
    return Trace.from_records(recs)


def _bs_kernel(mvl, cfg):
    """Jaxpr-frontend spec: 22 option streams in, the characterized 269-op
    pricing chain, 5 result streams out."""
    vl = min(mvl, cfg.mvl) if cfg else mvl
    ins = tuple(fe.Stream(f"opt{i}", _BS_FOOTPRINT_KB)
                for i in range(_BS_MEM_PER - 5))
    outs = tuple(fe.Stream(f"price{i}", _BS_FOOTPRINT_KB) for i in range(5))

    def fn(*streams):
        win = fe.chain_ops(_BS_ARITH_PER, _BS_MIX, seeds=(1.0, 2.0), vl=vl)
        return tuple(win[:5])

    return [fe.ScalarWork(_BS_S1), fe.KernelBody(fn, vl, ins=ins, outs=outs)]


# ===========================================================================
# Jacobi-2D (Table 5).  PolyBench large, 4,000 iterations.
#   chunks@8 = 13,056,000 (65,280,000 mem / 5 per chunk)
#   per chunk: 5 mem (4 loads + 1 store), 19.906 arith, 4.977 slides
#   vector_ops = 3,121,152,000 + 4000*mvl   (the per-iteration vsetconst)
#   scalar fit: s1 = 87.16/chunk, s0 = 137,308,272
#     (predicts 279.62M @MVL=64 vs published 279.60M: 0.006%)
# ===========================================================================

_J2_CHUNK8 = 13_056_000
_J2_MEM_PER, _J2_ARITH_PER, _J2_MANIP_PER = 5, 19.906, 4.977
_J2_S0, _J2_S1 = 137_308_272, 87.16
_J2_MIX = {"simple": 0.6, "mul": 0.4}
# grid points per sweep = chunks/iter x 8 elems; the stencil re-reads the
# A/B grids once per iteration, so the stream footprint is both grids
_J2_GRID_KB = 2 * (_J2_CHUNK8 / 4000 * 8) * 8 / 1024      # ~408 KB


def _j2_counts(mvl):
    chunks = _J2_CHUNK8 * 8 / mvl
    return Counts(
        scalar_code_total=4_797_698_032,
        scalar_instrs=_J2_S0 + _J2_S1 * chunks,
        vector_mem=_J2_MEM_PER * chunks,
        vector_arith=_J2_ARITH_PER * chunks,
        vector_manip=_J2_MANIP_PER * chunks,
        vector_ops=3_121_152_000 + 4000 * mvl,
    )


def _j2_body(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    recs = [scalar_block(_J2_S1)]
    for i in range(4):
        recs.append(vload(vl, dst=i, footprint_kb=_J2_GRID_KB))
    recs.append(vslide(vl, src1=0, dst=4))
    recs.append(vslide(vl, src1=0, dst=5))
    recs += _arith_seq(20, _J2_MIX, vl, start_reg=6)
    recs.append(vslide(vl, src1=6, dst=20))
    recs.append(vslide(vl, src1=7, dst=21))
    recs.append(vslide(vl, src1=8, dst=22))
    recs.append(vstore(vl, src1=20, footprint_kb=_J2_GRID_KB))
    return Trace.from_records(recs)


def _j2_kernel(mvl, cfg):
    """Jaxpr-frontend spec: the rolls lower to VSLIDEs, the stencil update to
    the characterized 20-op chain."""
    vl = min(mvl, cfg.mvl) if cfg else mvl
    ins = tuple(fe.Stream(f"grid{i}", _J2_GRID_KB) for i in range(4))

    def fn(a, b, c, d):
        up = jnp.roll(a, 1)
        down = jnp.roll(a, -1)
        win = fe.chain_ops(20, _J2_MIX, seeds=(0.2,), vl=vl)
        s1 = jnp.roll(win[0], 1)
        s2 = jnp.roll(win[1], 1)   # noqa: F841 boundary-fixup slides: traced
        s3 = jnp.roll(win[2], 1)   # noqa: F841 (and timed) though unstored
        return s1

    return [fe.ScalarWork(_J2_S1),
            fe.KernelBody(fn, vl, ins=ins,
                          outs=(fe.Stream("grid_out", _J2_GRID_KB),))]


# ===========================================================================
# Particle Filter (Table 6).  vfirst/vpopc mask ops -> scalar-core stalls.
#   arith instr fit: A/mvl + a0, A = 12,359,078,569, a0 = 657,519
#   mem   instr fit: M/mvl + m0, M = 12,861,315,  m0 = 33
#   ops fit: 12,371,423,928 + 659,566*mvl
#   scalar fit: s0 = 1,139,468,117, s1K = 1.845e10 (s = s0 + s1K/mvl)
#     (predicts 1,427.8M @64 vs published 1,423.6M: 0.3%)
# ===========================================================================

_PF_MIX = {"simple": 0.50, "mul": 0.30, "div": 0.05, "trans": 0.15}
# particle state arrays (positions/weights, ~100k particles of 8-B doubles)
_PF_STATE_KB = 781.0


def _pf_counts(mvl):
    return Counts(
        scalar_code_total=20_232_505_095,
        scalar_instrs=1_139_468_117 + 1.845e10 / mvl,
        vector_mem=12_861_315 / mvl + 33,
        vector_arith=12_359_078_569 / mvl + 657_519,
        vector_ops=12_371_423_928 + 659_566 * mvl,
    )


def _pf_chunks(mvl):
    # one "chunk" = one guess-update inner iteration over MVL particles
    return 12_359_078_569 / mvl / 960  # ~960 arith per chunk body


def _pf_body(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    recs = [vload(vl, dst=0, footprint_kb=_PF_STATE_KB)]
    # Box-Muller + motion model: log/cos/sqrt heavy
    recs += _arith_seq(760, _PF_MIX, vl)
    # sequential-search (guess update): every inner iteration compares, runs
    # vfirst.m/vpopc.m and hands the result to the scalar core, which decides
    # how to continue — the §5.4 serialization that erases all speedup
    for _ in range(16):
        recs += _arith_seq(11, {"simple": 1.0}, vl)
        recs.append(vmask_scalar(vl, src1=5))
        recs.append(vmask_scalar(vl, src1=6))
        recs.append(scalar_block(84, dep_scalar=True))
    return Trace.from_records(recs)


def _pf_kernel(mvl, cfg):
    """Jaxpr-frontend spec: the Box-Muller/motion chain from the jaxpr; the
    vfirst/vpopc round trips of the guess update are declared RawRecords
    (no JAX analogue) followed by the dependent scalar decision."""
    vl = min(mvl, cfg.mvl) if cfg else mvl

    def motion(state):
        fe.chain_ops(760, _PF_MIX, seeds=(0.5,), vl=vl)
        return state

    def search(i):
        def fn():
            return fe.chain_ops(11, {"simple": 1.0}, seeds=(0.5,), vl=vl)[0]
        return fn

    segs = [fe.KernelBody(motion, vl,
                          ins=(fe.Stream("particles", _PF_STATE_KB),))]
    for i in range(16):
        segs.append(fe.KernelBody(search(i), vl))
        segs.append(fe.RawRecords((vmask_scalar(vl, src1=5),
                                   vmask_scalar(vl, src1=6))))
        segs.append(fe.ScalarWork(84, dep_scalar=True))
    return segs


# ===========================================================================
# Pathfinder (Table 7).  26% element-manipulation instructions.
#   chunks@8 = 20,054,016; per chunk: 5 mem, 6 arith, 4 slides (5:6:4 of 15)
#   vector_ops = 2,406,481,920 (constant)
#   scalar fit: s0 = 268,401,305, s1 = 38.33
#     (predicts 364.49M @64 vs published 364.49M: 0.002%)
# ===========================================================================

_PATH_CHUNK8 = 20_054_016
_PATH_S0, _PATH_S1 = 268_401_305, 38.33
# one 100k-column row of 8-B path costs; the result row is re-read on the
# next row pass, the wall is streamed once (cold: footprint = whole wall)
_PATH_ROW_KB = 100_000 * 8 / 1024                          # ~781 KB
_PATH_WALL_KB = _PATH_CHUNK8 * 8 * 8 / 1024                # cold stream


def _path_counts(mvl):
    chunks = _PATH_CHUNK8 * 8 / mvl
    return Counts(
        scalar_code_total=6_213_455_512,
        scalar_instrs=_PATH_S0 + _PATH_S1 * chunks,
        vector_mem=5 * chunks,
        vector_arith=6 * chunks,
        vector_manip=4 * chunks,
        vector_ops=2_406_481_920,
    )


def _path_body(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    recs = [scalar_block(_PATH_S1)]
    recs.append(vload(vl, dst=0, footprint_kb=_PATH_WALL_KB))
    recs.append(vload(vl, dst=1, footprint_kb=_PATH_ROW_KB))
    recs.append(vload(vl, dst=2, footprint_kb=_PATH_ROW_KB))
    recs.append(vslide(vl, src1=1, dst=3))
    recs.append(vslide(vl, src1=1, dst=4))
    # min(left, center, right) + add weight
    recs.append(varith(vl, FU_SIMPLE, src1=3, src2=1, dst=5))
    recs.append(varith(vl, FU_SIMPLE, src1=5, src2=4, dst=6))
    recs.append(varith(vl, FU_SIMPLE, src1=6, src2=0, dst=7))
    recs.append(varith(vl, FU_SIMPLE, src1=7, src2=2, dst=8))
    recs.append(vslide(vl, src1=8, dst=9))
    recs.append(vslide(vl, src1=8, dst=10))
    recs.append(varith(vl, FU_SIMPLE, src1=9, src2=10, dst=11))
    recs.append(varith(vl, FU_SIMPLE, src1=11, src2=8, dst=12))
    recs.append(vload(vl, dst=13, footprint_kb=_PATH_ROW_KB))
    recs.append(vstore(vl, src1=12, footprint_kb=_PATH_ROW_KB))
    return Trace.from_records(recs)


def _path_kernel(mvl, cfg):
    """Jaxpr-frontend spec: the real min-propagation dataflow — slides and
    arith derive from the jaxpr with true operand dependencies on the loads
    (the hand-coded body reads the same registers).  The next row's block is
    fetched while the result is stored (software pipelining, as the
    hand-coded body orders it)."""
    vl = min(mvl, cfg.mvl) if cfg else mvl
    ins = (fe.Stream("wall", _PATH_WALL_KB),
           fe.Stream("row", _PATH_ROW_KB),
           fe.Stream("row_prev", _PATH_ROW_KB))

    def fn(wall, row, row_prev):
        left = jnp.roll(row, 1)
        right = jnp.roll(row, -1)
        m1 = jnp.minimum(left, row)
        m2 = jnp.minimum(m1, right)
        c1 = m2 + wall
        c2 = c1 + row_prev
        s3 = jnp.roll(c2, 1)
        s4 = jnp.roll(c2, -1)
        m3 = jnp.minimum(s3, s4)
        m4 = jnp.minimum(m3, c2)
        return m4

    return [fe.ScalarWork(_PATH_S1),
            fe.KernelBody(fn, vl, ins=ins, outs=("cost",)),
            fe.KernelBody(lambda nxt, cost: cost, vl,
                          ins=(fe.Stream("row_next", _PATH_ROW_KB), "cost"),
                          outs=(fe.Stream("row_out", _PATH_ROW_KB),))]


# ===========================================================================
# Streamcluster (Table 8).  Memory-bound; dist() = loads + mul-sub + reduce.
#   calls = 59,533,158 (mem@128); dims = 128 (large input)
#   per call: ceil(128/mvl) chunks of (1 load + 1 arith) + 2 full-MVL arith
#   ops = 15,240,488,448 + 2*calls*mvl   (exact on all three published MVLs)
#   scalar fit: s0 = 1,944,277,308, s1 = 2.50/chunk
#     (predicts 2,241.9M @64 vs published 2,241.9M: 0.001%)
# ===========================================================================

_SC_CALLS = 59_533_158
_SC_DIMS = 128
_SC_MIX = {"simple": 0.5, "mul": 0.5}
# active set of a dist() call sequence: the candidate-center block plus the
# current window of streaming points (the full point set is ~60 MB, but the
# centers are re-read every call — this is the reuse distance that matters,
# and it is the lever of the Fig-10 LLC study: 256 KB spills it, 1 MB holds)
_SC_WSET_KB = 768.0


def _sc_counts(mvl):
    per_call = math.ceil(_SC_DIMS / mvl)
    chunks = _SC_CALLS * per_call
    return Counts(
        scalar_code_total=36_068_326_139,
        scalar_instrs=1_944_277_308 + 2.50 * chunks,
        vector_mem=chunks,
        vector_arith=chunks + 2 * _SC_CALLS,
        vector_ops=2 * _SC_DIMS * _SC_CALLS + 2 * _SC_CALLS * mvl,
    )


def _sc_chunks(mvl):
    return float(_SC_CALLS)  # one body = one dist() call


def _sc_body(mvl, cfg):
    vl_eff = min(mvl, _SC_DIMS, cfg.mvl if cfg else mvl)
    iters = math.ceil(_SC_DIMS / vl_eff)
    recs = []
    # streaming distance computation: L2-resident at best (memory bound)
    for i in range(iters):
        recs.append(scalar_block(2.5))
        recs.append(vload(vl_eff, dst=i % 8, footprint_kb=_SC_WSET_KB))
        recs.append(varith(vl_eff, FU_MUL, src1=i % 8, src2=8, dst=9 + i % 8))
    # the reduction runs at the requested VL (<= 128 dims), not the raw MVL
    recs.append(vreduce(vl_eff, src1=9, dst=20, fu=FU_SIMPLE))
    recs.append(vmask_scalar(vl_eff, src1=20))
    # the scalar core evaluates the center-opening cost before the next call
    recs.append(scalar_block(30, dep_scalar=True))
    return Trace.from_records(recs)


def _sc_kernel(mvl, cfg):
    """Jaxpr-frontend spec: each dist() sub-block is load + multiply with a
    real load->arith dependency (like the hand-coded body), chained through
    a named carry into the final reduction."""
    vl_eff = min(mvl, _SC_DIMS, cfg.mvl if cfg else mvl)
    iters = math.ceil(_SC_DIMS / vl_eff)
    segs = []
    for i in range(iters):
        segs.append(fe.ScalarWork(2.5))
        if i == 0:
            seg_fn, seg_ins = (lambda x: x * x), \
                (fe.Stream("block0", _SC_WSET_KB),)
        else:
            seg_fn, seg_ins = (lambda x, acc: acc * x), \
                (fe.Stream(f"block{i}", _SC_WSET_KB), "acc")
        segs.append(fe.KernelBody(seg_fn, vl_eff, ins=seg_ins, outs=("acc",)))
    segs.append(fe.KernelBody(lambda acc: jnp.sum(acc), vl_eff, ins=("acc",)))
    segs.append(fe.RawRecords((vmask_scalar(vl_eff, src1=20),)))
    segs.append(fe.ScalarWork(30, dep_scalar=True))
    return segs


# ===========================================================================
# Swaptions (Table 9).  HJM Monte-Carlo; RanUnif/serialB/CumNormalInv.
#   elems = 17,314,316,288 (constant over MVL); instr = elems/mvl
#   mem fraction = 370,323,456 / 2,164,289,536 = 0.17110
#   body = 29 instr (5 mem + 24 arith); chunks = instr/29
#   scalar fit: s0 = 266,357,033, s1 = 52.35/chunk
#     (predicts 754.7M @64 vs published 751.9M: 0.4%)
# ===========================================================================

_SW_ELEMS = 17_314_316_288
_SW_MIX = {"simple": 0.50, "mul": 0.35, "div": 0.05, "trans": 0.10}


def _sw_counts(mvl):
    instr = _SW_ELEMS / mvl
    return Counts(
        scalar_code_total=26_846_776_223,
        scalar_instrs=266_357_033 + 52.35 * instr / 29,
        vector_mem=0.17110 * instr,
        vector_arith=(1 - 0.17110) * instr,
        vector_ops=_SW_ELEMS,
    )


def _sw_chunks(mvl):
    return _SW_ELEMS / mvl / 29


def _sw_footprint_kb(vl):
    """Fig-10 lever: the HJM working set grows with the block size (=VL) —
    ~350 vectors of VL doubles live across the HJM path state (calibrated to
    the paper's stated observation: a 256 KB L2 degrades from MVL=128 up, a
    1 MB L2 holds through MVL=256).  At small VL it fits the L1 (22 KB at
    MVL=8); at MVL=128 it is 350 KB (spills 256 KB, fits 1 MB) and at
    MVL=256 it is 700 KB — the analytic model in repro.core.memory turns the
    footprint into the observed degradation."""
    return vl * 8 * 350 / 1024


def _sw_body(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    fp = _sw_footprint_kb(vl)
    recs = [scalar_block(52.35)]
    for i in range(4):
        recs.append(vload(vl, dst=i, footprint_kb=fp))
    recs += _arith_seq(24, _SW_MIX, vl)
    recs.append(vstore(vl, src1=10, footprint_kb=fp))
    return Trace.from_records(recs)


def _sw_kernel(mvl, cfg):
    """Jaxpr-frontend spec: HJM path-state streams with the VL-scaled
    footprint (the Fig-10 lever), characterized 24-op chain.  The chain runs
    over an 8-wide rotating window (not the default 16) so each result is
    consumed again within a few ops, matching the hand-coded body's
    rotating-register chain density — the small-MVL steady-state time is
    startup-latency bound and sensitive to exactly this."""
    vl = min(mvl, cfg.mvl) if cfg else mvl
    fp = _sw_footprint_kb(vl)
    ins = tuple(fe.Stream(f"hjm{i}", fp) for i in range(4))

    def fn(*streams):
        return fe.chain_ops(24, _SW_MIX, seeds=(1.5,), vl=vl, window=8)[6]

    return [fe.ScalarWork(52.35),
            fe.KernelBody(fn, vl, ins=ins, outs=(fe.Stream("path", fp),))]


# ===========================================================================
# Canneal (Table 4).  Irregular DLP, short vectors (fan-in/out <= 22),
# indexed memory, reduction + scalar decision per swap, move/spill overhead
# proportional to MVL.
#   N_swaps = 1,920,000 (PARSEC large: 15,000 moves x 128 temperature steps)
#   requested-VL instrs (MVL>=32): 210,116,186 (= 271,044,357 - 60,928,171
#     full-MVL moves/spills, from the ops-vs-MVL slope 60.93e6/element)
#   E[fan] = 10.15 (avg requested VL); iteration multipliers fitted:
#     E[ceil(f/8)] = 1.395, E[ceil(f/16)] = 1.003  (published @8/@16 counts)
#   per extra iteration: 99.4 scalar instructions (consistent across @8/@16)
# ===========================================================================

_CA_N = 1_920_000
_CA_REQ = 210_116_186
_CA_MOVES = 60_928_171
_CA_MIX = {"simple": 1.0}
# hot slice of the netlist the random swap walk actually revisits between
# reuses (~3 MB of a far larger netlist): indexed loads miss both caches at
# 256 KB, and a 1 MB LLC captures a third of it — the memory.py model turns
# this into the canneal LLC sensitivity
_CA_HOT_KB = 3072.0
# fan-out distribution (fitted to E[f]=10.15, P(f>8)=.395, P(f>16)=.003)
_CA_FAN = {6: 0.18, 8: 0.422, 12: 0.15, 14: 0.12, 16: 0.125, 20: 0.003}


def _ca_iter_mult(mvl):
    return sum(p * math.ceil(f / mvl) for f, p in _CA_FAN.items())


# Empirical iteration multipliers fitted per published column (Table 4):
# memory instructions repeat per extra iteration more than arithmetic does
# (the two indexed loads run every iteration; arithmetic shrinks with the
# remaining VL), and MVL=8 spills run at effective VL 5.28, not 8.
_CA_MEM_BASE = 37_269_628
_CA_ARITH_REQ = 172_846_558            # 233,774,729 - moves
_CA_MEM_MULT = {8: 1.6069, 16: 1.00436}
_CA_ARITH_MULT = {8: 1.3489, 16: 1.00251}
_CA_REQ_OPS = 2_128_669_087            # = ops@32 - 32*moves
_CA_MOVES_VL = {8: 5.277}


def _ca_counts(mvl):
    mem_mult = _CA_MEM_MULT.get(mvl, _ca_iter_mult(mvl) if mvl < 8 else 1.0)
    ar_mult = _CA_ARITH_MULT.get(mvl, 1.0)
    mem = _CA_MEM_BASE * mem_mult
    arith = _CA_ARITH_REQ * ar_mult
    extra_iter = (_ca_iter_mult(mvl) - 1.0) * 2 * _CA_N
    moves_vl = _CA_MOVES_VL.get(mvl, mvl)
    return Counts(
        scalar_code_total=5_239_983_271,
        scalar_instrs=3_217_635_854 + 99.4 * extra_iter,
        vector_mem=mem,
        vector_arith=arith + _CA_MOVES,   # moves/spills counted as arith-class
        # requested element work is MVL-independent (2.13e9); moves/spills
        # execute at full MVL (the paper's large-MVL slowdown culprit, §5.2)
        vector_ops=_CA_REQ_OPS + _CA_MOVES * moves_vl,
    )


def _ca_chunks(mvl):
    return float(_CA_N)


def _ca_body(mvl, cfg):
    vl_req = 12  # representative fan size (E[f] ~ 10.15, use 12)
    vl = min(vl_req, mvl, cfg.mvl if cfg else mvl)
    iters = math.ceil(vl_req / vl)
    # moves/spills execute at the configured MVL regardless of the requested
    # VL (§4.1.2 — the large-MVL slowdown culprit), so they key off cfg.mvl
    # even when the suite clamps the body to the app's max requested VL
    mvl_eff = cfg.mvl if cfg else mvl
    recs = []
    for _ in range(2):  # two picked nodes
        # moves of the coordinate arguments (full MVL, §4.1.2)
        for i in range(int(round(_CA_MOVES / _CA_N / 2))):
            recs.append(vmove(mvl_eff, src1=i % 4, dst=8 + i % 4))
        for it in range(iters):
            recs.append(scalar_block(99.4 if it else 12))
            # pseudo-random netlist walk: indexed loads mostly miss to DRAM
            recs.append(vload(vl, dst=0, footprint_kb=_CA_HOT_KB,
                              pattern=MEM_INDEXED))
            recs.append(vload(vl, dst=1, footprint_kb=_CA_HOT_KB,
                              pattern=MEM_INDEXED))
            recs += _arith_seq(22, _CA_MIX, vl)
        recs.append(vreduce(vl, src1=6, dst=20))
        recs.append(vmask_scalar(vl, src1=20))
        # the scalar core computes the final routing cost + swap decision
        # before the next pair is dispatched (§4.1.2 "intensive communication")
        recs.append(scalar_block(820, dep_scalar=True))
    return Trace.from_records(recs)


def _ca_kernel(mvl, cfg):
    """Jaxpr-frontend spec: indexed netlist streams and the fan-in cost chain
    derive from the jaxpr; the full-MVL argument moves/spills are declared
    RawRecords (ABI artifacts, no JAX analogue), and the swap decision is a
    dependent ScalarWork after the reduction hands its result over."""
    vl_req = 12
    vl = min(vl_req, mvl, cfg.mvl if cfg else mvl)
    iters = math.ceil(vl_req / vl)
    mvl_eff = cfg.mvl if cfg else mvl
    n_mv = int(round(_CA_MOVES / _CA_N / 2))

    def walk_fn(a, b):
        return fe.chain_ops(22, _CA_MIX, seeds=(1.0,), vl=vl)[0]

    segs = []
    for _ in range(2):  # two picked nodes
        segs.append(fe.RawRecords(tuple(
            vmove(mvl_eff, src1=i % 4, dst=8 + i % 4) for i in range(n_mv))))
        for it in range(iters):
            segs.append(fe.ScalarWork(99.4 if it else 12))
            segs.append(fe.KernelBody(
                walk_fn, vl,
                ins=(fe.Stream("net_a", _CA_HOT_KB, pattern=MEM_INDEXED),
                     fe.Stream("net_b", _CA_HOT_KB, pattern=MEM_INDEXED)),
                outs=("cost",)))
        segs.append(fe.KernelBody(lambda cost: jnp.sum(cost), vl,
                                  ins=("cost",)))
        segs.append(fe.RawRecords((vmask_scalar(vl, src1=20),)))
        segs.append(fe.ScalarWork(820, dep_scalar=True))
    return segs


# ===========================================================================

APPS = {
    "blackscholes": App("blackscholes", _bs_counts, _bs_body,
                        lambda mvl: _BS_UNITS / mvl, _BS_MIX,
                        init_scalar=573_256_509, kernel=_bs_kernel,
                        asm="blackscholes.s",
                        notes="regular DLP; PDE pricing; Table 3 / Fig 4"),
    "canneal": App("canneal", _ca_counts, _ca_body, _ca_chunks, _CA_MIX,
                   max_vl=22, kernel=_ca_kernel, asm="canneal.s",
                   notes="irregular DLP; indexed loads; Table 4 / Fig 5"),
    "jacobi-2d": App("jacobi-2d", _j2_counts, _j2_body,
                     lambda mvl: _J2_CHUNK8 * 8 / mvl, _J2_MIX,
                     kernel=_j2_kernel, asm="jacobi2d.s",
                     notes="stencil; slides stress interconnect; Table 5 / Fig 6"),
    "particlefilter": App("particlefilter", _pf_counts, _pf_body, _pf_chunks,
                          _PF_MIX, kernel=_pf_kernel, asm="particlefilter.s",
                          notes="mask ops stall scalar core; Table 6 / Fig 7"),
    "pathfinder": App("pathfinder", _path_counts, _path_body,
                      lambda mvl: _PATH_CHUNK8 * 8 / mvl, {"simple": 1.0},
                      kernel=_path_kernel, asm="pathfinder.s",
                      notes="26% element-manip instrs; Table 7 / Fig 8"),
    "streamcluster": App("streamcluster", _sc_counts, _sc_body, _sc_chunks,
                         _SC_MIX, max_vl=_SC_DIMS, kernel=_sc_kernel,
                         asm="streamcluster.s",
                         notes="memory bound; reduction/call; Table 8 / Fig 9"),
    "swaptions": App("swaptions", _sw_counts, _sw_body, _sw_chunks, _SW_MIX,
                     kernel=_sw_kernel, asm="swaptions.s",
                     notes="HJM Monte-Carlo; LLC sensitivity; Table 9 / Fig 10"),
}

# The paper's RiVec suite: both frontends exist and must cross-validate
# (repro.core.frontend.cross_validate_all).
RIVEC_APPS = tuple(sorted(APPS))

# ---------------------------------------------------------------------------
# trace-source variants: "<app>:asm" names the same app with its loop body
# decoded from the RVV assembly corpus (src/repro/asm, repro.core.rvv)
# instead of the hand-coded `body`.  The suite/DSE layers resolve names
# through `app_for`/`body_for`/`chunks_for`, so asm-sourced apps ride
# `sweep_all`, the golden table and `dse.explore` unchanged.
# ---------------------------------------------------------------------------

ASM_SUFFIX = ":asm"


def split_variant(app_name: str) -> tuple[str, str]:
    """``"canneal:asm" -> ("canneal", "asm")``; plain names are "hand"."""
    if app_name.endswith(ASM_SUFFIX):
        return app_name[:-len(ASM_SUFFIX)], "asm"
    return app_name, "hand"


def app_for(app_name: str) -> App:
    """The registry entry backing a (possibly variant-suffixed) app name."""
    return APPS[split_variant(app_name)[0]]


def chunks_for(app_name: str, mvl: int, cfg=None) -> float:
    """Loop-body executions at this MVL.  For ``:asm`` variants the count is
    *derived from the decoded kernel* (its AVL / loop counter), not the
    closed form — the two agree to ~1e-8 (the .s AVLs are the rounded
    characterized totals)."""
    base, source = split_variant(app_name)
    if source == "asm":
        from repro.core import rvv
        return rvv.asm_chunks(base, mvl, cfg)
    return APPS[base].chunks(mvl)

# Frontend-only ML workloads (no hand-coded bodies: the lowered kernel IS
# the body) — registered here so the whole toolchain (suite sweeps, golden
# regression, module_stress) sees one app registry.
from repro.core import workloads_ml as _ml  # noqa: E402  (needs App/Counts)

APPS.update(_ml.make_apps(App, Counts))


# ---------------------------------------------------------------------------
# Scalar-pipeline profiles (repro.core.scalar_pipeline): the per-app scalar
# -code event profile the dual-issue in-order baseline model consumes.
# branch/load/raw/fusible fractions are hand-set from each app's code
# character (commented); mem_stall_cyc is the one FITTED parameter per app
# (benchmarks/calibrate.py solves it closed-form against the §5 anchors and
# prints this table).  particlefilter additionally carries the named
# roi_instr_fraction correction (docs/calibration.md).
# ---------------------------------------------------------------------------

SCALAR_PROFILES = {
    # straight-line FP pricing; few, predictable branches; streams 13.8 MB
    # of option data -> most scalar loads miss the LLC (large mem stall)
    "blackscholes": ScalarProfile(branch_frac=0.10, branch_miss_rate=0.06,
                                  load_frac=0.22, raw_frac=0.35,
                                  fusible_frac=0.30, mem_stall_cyc=11.03),
    # pointer-chasing netlist walk: branchy, mispredict-prone, indexed loads
    # over a ~3 MB hot set that misses both caches
    "canneal": ScalarProfile(branch_frac=0.18, branch_miss_rate=0.12,
                             load_frac=0.28, raw_frac=0.30,
                             fusible_frac=0.20, mem_stall_cyc=5.25),
    # tight stencil loops: highly predictable branches, grid streams spill L1
    "jacobi-2d": ScalarProfile(branch_frac=0.08, branch_miss_rate=0.03,
                               load_frac=0.30, raw_frac=0.30,
                               fusible_frac=0.30, mem_stall_cyc=7.49),
    # Box-Muller/transcendental-heavy with a data-dependent sequential
    # search; the ROI correction is the named published-count term (§5.4)
    "particlefilter": ScalarProfile(branch_frac=0.14, branch_miss_rate=0.10,
                                    load_frac=0.22, raw_frac=0.35,
                                    fusible_frac=0.25, mem_stall_cyc=4.0,
                                    roi_instr_fraction=0.0763),
    # min-propagation: compare/branch dense, row arrays mostly L2-resident
    "pathfinder": ScalarProfile(branch_frac=0.16, branch_miss_rate=0.10,
                                load_frac=0.25, raw_frac=0.35,
                                fusible_frac=0.30, mem_stall_cyc=5.73),
    # dist() call chain over a spilling working set: memory-bound scalar too
    "streamcluster": ScalarProfile(branch_frac=0.12, branch_miss_rate=0.08,
                                   load_frac=0.28, raw_frac=0.30,
                                   fusible_frac=0.25, mem_stall_cyc=4.31),
    # HJM Monte-Carlo: compute-bound, small working set at scalar block sizes
    "swaptions": ScalarProfile(branch_frac=0.10, branch_miss_rate=0.06,
                               load_frac=0.20, raw_frac=0.30,
                               fusible_frac=0.30, mem_stall_cyc=1.43),
    # ML workloads (no paper anchors): profiles modeled, mem_stall set for
    # continuity with the previously modeled baselines (docs/calibration.md)
    "flash_attention": ScalarProfile(branch_frac=0.06, branch_miss_rate=0.04,
                                     load_frac=0.25, raw_frac=0.30,
                                     fusible_frac=0.30, mem_stall_cyc=1.90),
    # scalar core is itself DRAM-bound streaming the multi-MB KV cache
    "decode_attention": ScalarProfile(branch_frac=0.06, branch_miss_rate=0.04,
                                      load_frac=0.28, raw_frac=0.30,
                                      fusible_frac=0.30, mem_stall_cyc=17.87),
    "ssd_scan": ScalarProfile(branch_frac=0.08, branch_miss_rate=0.05,
                              load_frac=0.25, raw_frac=0.30,
                              fusible_frac=0.30, mem_stall_cyc=0.68),
}


def scalar_profile_for(app_name: str) -> ScalarProfile:
    """The scalar profile backing a (possibly variant-suffixed) app name —
    trace-source variants share the base app's scalar code."""
    return SCALAR_PROFILES[split_variant(app_name)[0]]


# With the engine batched, rebuilding ~300-entry traces per config point is a
# measurable Python-side cost; bodies are pure functions of (mvl, cfg) and
# VectorEngineConfig is frozen/hashable, so cache on the config itself.
_BODY_CACHE: dict = {}


def body_for(app_name: str, mvl: int, cfg=None) -> Trace:
    """Cached loop-body trace for a (possibly variant-suffixed) app name:
    ``APPS[name].body(mvl, cfg)``, or the decoded RVV corpus body for
    ``"<name>:asm"`` (callers must not mutate)."""
    key = (app_name, mvl, cfg)
    out = _BODY_CACHE.get(key)
    if out is None:
        base, source = split_variant(app_name)
        if source == "asm":
            from repro.core import rvv
            out = rvv.asm_body(base, mvl, cfg)
        else:
            out = APPS[base].body(mvl, cfg)
        _BODY_CACHE[key] = out
    return out


# The asm-sourced suite variant (rides sweep_all / dse.explore / the golden
# table): every app whose corpus entry exists — the RiVec seven plus the
# codegen-emitted ML workloads (flash_attention / decode_attention /
# ssd_scan, PR 7).
ASM_APPS = tuple(f"{a}{ASM_SUFFIX}" for a in sorted(APPS) if APPS[a].asm)
