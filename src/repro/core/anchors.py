"""The paper's §5 speedup anchors — the repo-wide accuracy contract.

Eleven published (app, MVL, lanes) -> speedup points read off Figures 4-9.
``"eq"`` anchors are numeric targets (model/paper inside the
[``EQ_LO``, ``EQ_HI``] band, the tolerance the whole repo documents);
``"lt"`` anchors encode the paper's qualitative claims — canneal degrades
below scalar at MVL>=128 (§5.2) and no particlefilter configuration beats
the scalar core (§5.4) — as hard upper bounds.

One table, three consumers: ``tests/test_suite_timing.py`` (tier-1),
``repro.core.scalar_pipeline --check`` (the CI scalar-scorecard gate) and
``benchmarks/calibrate.py --scorecard`` (per-anchor rel-err report).
"""
from __future__ import annotations

# (app, mvl, lanes, paper speedup, kind)
ANCHORS = (
    ("blackscholes", 8, 1, 2.22, "eq"),
    ("jacobi-2d", 8, 1, 1.79, "eq"),
    ("jacobi-2d", 256, 1, 2.99, "eq"),
    ("canneal", 16, 1, 1.64, "eq"),
    ("canneal", 16, 8, 1.88, "eq"),
    ("canneal", 256, 1, 1.0, "lt"),
    ("particlefilter", 8, 1, 1.0, "lt"),
    ("particlefilter", 256, 8, 1.0, "lt"),
    ("pathfinder", 8, 1, 1.8, "eq"),
    ("streamcluster", 8, 1, 1.68, "eq"),
    ("swaptions", 8, 1, 1.03, "eq"),
)

# documented tolerance band for "eq" anchors: EQ_LO <= model/paper <= EQ_HI
EQ_LO, EQ_HI = 0.80, 1.25
# "lt" anchors are hard qualitative bounds: model <= target * LT_SLACK
LT_SLACK = 1.0
