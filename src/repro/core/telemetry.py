"""Unified telemetry: cycle attribution, timelines, latency histograms.

Three consumers share the schema defined here (``SCHEMA`` rows produced by
:func:`snapshot_row`):

* **Engine profiling** — ``engine.simulate(..., collect_stats=True)`` returns
  per-cause cycle counters (``engine.STALL_KINDS``) whose sum reconstructs
  ``time`` (the event-sum identity, enforced by ``--smoke``).  This module
  rolls them up into per-module fractions (:func:`module_fractions`), a
  per-app × per-config scorecard (:func:`scorecard` → :class:`ProfileReport`)
  and a Chrome Trace Event Format timeline (:func:`chrome_trace`) loadable in
  ``chrome://tracing`` / https://ui.perfetto.dev.
* **Serving** — ``repro.serve.sim_service`` records request latencies into a
  :class:`LatencyHistogram` (bounded, log-spaced) and emits periodic
  ``snapshot_row`` stats.
* **DSE / search** — ``repro.core.dse.explore`` and ``repro.core.search``
  log per-phase wall-clock + cache-counter rows in the same shape.

The module-stress classification here is the *mechanistic* twin of
``benchmarks/module_stress.py``'s differential (knob-ablation) matrix; the
two are cross-checked in CI.

>>> h = LatencyHistogram()
>>> for ms in (1.0, 2.0, 100.0): h.add(ms / 1e3)
>>> h.count
3
>>> 0.5e-3 < h.percentile(0.5) < 4e-3
True
>>> module_of("exec_mem")
'memory'
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

import numpy as np

from repro.core import engine as eng
from repro.core import isa

SCHEMA = "repro.telemetry/v1"


def snapshot_row(kind: str, **payload) -> dict:
    """One telemetry row: the shared envelope every subsystem emits."""
    return {"schema": SCHEMA, "kind": kind, **payload}


# --------------------------------------------------------------------------
# module rollup: STALL_KINDS -> the paper's stressed-module classification
# --------------------------------------------------------------------------
# §5's Table-8-style taxonomy: which hardware module an app leans on.
#   lanes        — arithmetic FU execution + waiting for a busy lane FU
#   memory       — VMU execution (cache/MSHR/DRAM cycles), VMU busy wait,
#                  memory-queue backpressure
#   interconnect — slides / reductions crossing the lane fabric (matches the
#                  differential matrix's "manip" definition exactly; the
#                  vfirst/vpopc mask->scalar path is scalar *communication*)
#   scalar       — residual scalar blocks, the scalar pipe carrying vector
#                  instructions, dep_scalar coupling round-trips, dispatch
#                  gating, and the vfirst/vpopc mask->scalar delivery
#   frontend     — structural sizing: ROB / rename / arith-queue fulls and
#                  the in-order issue gate
#   hazard       — RAW waits on vector register operands
MODULES: dict[str, tuple[str, ...]] = {
    "lanes": ("lane_wait", "exec_simple", "exec_mul", "exec_div",
              "exec_trans", "exec_move"),
    "memory": ("vmu_wait", "mq_full", "exec_mem"),
    "interconnect": ("exec_interconnect",),
    "scalar": ("scalar_work", "dep_scalar", "dispatch", "exec_mask"),
    "frontend": ("rob_full", "phys_full", "aq_full", "inorder"),
    "hazard": ("raw",),
}
_KIND_TO_MODULE = {k: m for m, ks in MODULES.items() for k in ks}
assert set(_KIND_TO_MODULE) == set(eng.STALL_KINDS)

FU_NAMES = ("simple", "mul", "div", "trans")


def module_of(stall_kind: str) -> str:
    """The hardware module a stall/exec cause rolls up into."""
    return _KIND_TO_MODULE[stall_kind]


def module_fractions(stalls: dict[str, float], time: float) -> dict[str, float]:
    """Fraction of total runtime attributed to each module (sums to ~1)."""
    t = max(time, 1e-12)
    out = {m: 0.0 for m in MODULES}
    for k, v in stalls.items():
        out[_KIND_TO_MODULE[k]] += v / t
    return out


def top_bottleneck(modules: dict[str, float]) -> str:
    """The dominant module; ties break toward the MODULES declaration order."""
    order = list(MODULES)
    return max(modules, key=lambda m: (modules[m], -order.index(m)))


# --------------------------------------------------------------------------
# per-app profiling scorecard
# --------------------------------------------------------------------------
def profile_app(app_name: str, cfg: eng.VectorEngineConfig,
                tiles: int = 8) -> dict:
    """Mechanistic profile of one (app, config) cell: simulate ``tiles``
    loop-body iterations with ``collect_stats`` and roll the attribution up
    into the scorecard row schema."""
    from repro.core import suite, tracegen
    mvl = suite.effective_mvl(app_name, cfg)
    body = tracegen.body_for(app_name, mvl, cfg)
    prof = eng.simulate(body.tile(tiles), cfg, collect_stats=True)
    time = prof["time"]
    stalls = prof["stalls"]
    mods = module_fractions(stalls, time)
    ident = abs(sum(stalls.values()) - time) / max(time, 1.0)
    t = max(time, 1e-12)
    return snapshot_row(
        "engine.profile",
        app=app_name, config=cfg.label(), tiles=tiles, time=time,
        stalls=stalls, modules=mods, top=top_bottleneck(mods),
        fu_occupancy={n: o / t for n, o in
                      zip(FU_NAMES, prof["occ_lane_fu"])},
        lane_busy_frac=prof["lane_busy"] / t,
        vmu_busy_frac=prof["vmu_busy"] / t,
        identity_rel_err=ident,
    )


@dataclass
class ProfileReport:
    """Per-app × per-config module-stress scorecard."""
    rows: list = field(default_factory=list)
    schema: str = SCHEMA

    def by_app(self) -> dict[str, list]:
        out: dict[str, list] = {}
        for r in self.rows:
            out.setdefault(r["app"], []).append(r)
        return out

    def to_dict(self) -> dict:
        return {"schema": self.schema, "kind": "engine.scorecard",
                "rows": self.rows}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    def table(self) -> str:
        """Human-readable scorecard (one line per row)."""
        lines = [f"{'app':16s} {'config':24s} {'top':12s} "
                 + " ".join(f"{m:>6s}" for m in MODULES)]
        for r in self.rows:
            lines.append(
                f"{r['app']:16s} {r['config']:24s} {r['top']:12s} "
                + " ".join(f"{r['modules'][m]:6.3f}" for m in MODULES))
        return "\n".join(lines)


def scorecard(apps=None, cfgs=None, tiles: int = 8) -> ProfileReport:
    """Profile every app × config cell mechanistically."""
    from repro.core import tracegen
    if apps is None:
        apps = sorted(tracegen.APPS)
    if cfgs is None:
        cfgs = [eng.VectorEngineConfig(mvl=64, lanes=4)]
    return ProfileReport(rows=[profile_app(a, c, tiles=tiles)
                               for a in apps for c in cfgs])


# --------------------------------------------------------------------------
# Chrome Trace Event Format / Perfetto timeline
# --------------------------------------------------------------------------
_TRACK_SCALAR, _TRACK_LANES, _TRACK_VMU = 0, 1, 2
_TRACK_NAMES = {_TRACK_SCALAR: "scalar pipe", _TRACK_LANES: "vector lanes",
                _TRACK_VMU: "VMU"}


def chrome_trace(trace: isa.Trace, cfg: eng.VectorEngineConfig,
                 label: str = "trace") -> dict:
    """One trace's instruction timeline in Chrome Trace Event Format.

    Load the JSON in ``chrome://tracing`` or https://ui.perfetto.dev: three
    tracks (scalar pipe / vector lanes / VMU), one complete-event span per
    record from issue to completion, preceded by a ``stall:<cause>`` span
    when the record waited visibly.  1 engine cycle is rendered as 1 µs
    (``ts``/``dur`` are in µs in the format; the engine clock is 1 GHz, so
    displayed µs = simulated µs × 1000).
    """
    prof = eng.simulate(trace, cfg, collect_stats=True)
    rec = prof["records"]
    kind = np.asarray(trace.kind)
    vl = np.asarray(trace.vl)
    fu = np.asarray(trace.fu)
    s_count = np.asarray(trace.scalar_count)
    events = [
        {"name": "process_name", "ph": "M", "pid": 0,
         "args": {"name": f"{label} @ {cfg.label()}"}},
    ] + [
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
         "args": {"name": name}} for tid, name in _TRACK_NAMES.items()
    ]
    for i in range(len(kind)):
        k = int(kind[i])
        if k == isa.NOP:
            continue
        start = float(rec["start"][i])
        mid = float(rec["issue"][i])
        end = float(rec["complete"][i])
        cause = eng.STALL_KINDS[int(rec["cause"][i])]
        if k == isa.SCALAR_BLOCK:
            tid = _TRACK_SCALAR
            name = f"scalar x{int(s_count[i])} ({FU_NAMES[int(fu[i])]})"
        else:
            tid = _TRACK_VMU if k in (isa.VLOAD, isa.VSTORE) else _TRACK_LANES
            name = f"{isa.KIND_NAMES[k]} vl={int(vl[i])}"
            if k == isa.VARITH:
                name += f" ({FU_NAMES[int(fu[i])]})"
        if mid > start:
            events.append({"name": f"stall:{cause}", "cat": "stall",
                           "ph": "X", "ts": start, "dur": mid - start,
                           "pid": 0, "tid": tid,
                           "args": {"record": i, "cause": cause}})
        if end > mid:
            events.append({"name": name, "cat": "exec", "ph": "X",
                           "ts": mid, "dur": end - mid, "pid": 0, "tid": tid,
                           "args": {"record": i}})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ns",
        "otherData": snapshot_row("engine.timeline", label=label,
                                  config=cfg.label(), time=prof["time"],
                                  stalls=prof["stalls"]),
    }


def write_chrome_trace(path: str, trace: isa.Trace,
                       cfg: eng.VectorEngineConfig,
                       label: str = "trace") -> dict:
    doc = chrome_trace(trace, cfg, label=label)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return doc


# --------------------------------------------------------------------------
# bounded log-spaced latency histogram (serving telemetry)
# --------------------------------------------------------------------------
class LatencyHistogram:
    """Fixed log-spaced latency buckets: percentiles without retaining every
    per-request latency record.  Default geometry spans 1 µs .. 100 s at 8
    buckets/decade (65 edges, 66 counters incl. under/overflow) — bounded
    memory no matter how many requests it absorbs."""

    def __init__(self, lo_s: float = 1e-6, hi_s: float = 1e2,
                 per_decade: int = 8, counts=None):
        self.lo_s, self.hi_s, self.per_decade = lo_s, hi_s, per_decade
        n = int(round(math.log10(hi_s / lo_s) * per_decade)) + 1
        self.edges = lo_s * (10.0 ** (np.arange(n) / per_decade))
        self.counts = (np.zeros(n + 1, np.int64) if counts is None
                       else np.asarray(counts, np.int64).copy())

    def add(self, seconds: float) -> None:
        self.counts[int(np.searchsorted(self.edges, seconds, "right"))] += 1

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def snapshot(self) -> np.ndarray:
        return self.counts.copy()

    def since(self, snapshot) -> "LatencyHistogram":
        """The histogram of everything added after ``snapshot`` was taken."""
        return LatencyHistogram(self.lo_s, self.hi_s, self.per_decade,
                                counts=self.counts - np.asarray(snapshot))

    def percentile(self, q: float) -> float:
        """q-quantile (q in [0,1]), geometrically interpolated within its
        bucket; under/overflow clamp to the histogram bounds."""
        total = self.count
        if total == 0:
            return 0.0
        target = q * total
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, target, "left"))
        if b == 0:
            return self.lo_s
        if b >= len(self.edges):
            return self.hi_s
        lo, hi = self.edges[b - 1], self.edges[b]
        prev = cum[b - 1]
        frac = (target - prev) / max(self.counts[b], 1)
        return float(lo * (hi / lo) ** min(max(frac, 0.0), 1.0))

    def to_dict(self) -> dict:
        """Sparse row form: only non-empty buckets are materialized."""
        nz = np.nonzero(self.counts)[0]
        return snapshot_row(
            "latency.hist", unit="s", lo_s=self.lo_s, hi_s=self.hi_s,
            per_decade=self.per_decade, count=self.count,
            buckets={int(i): int(self.counts[i]) for i in nz},
            p50_s=self.percentile(0.50), p99_s=self.percentile(0.99),
            p999_s=self.percentile(0.999))


# --------------------------------------------------------------------------
# smoke gate (scripts/ci.sh profile-smoke)
# --------------------------------------------------------------------------
def _smoke() -> int:
    from repro.core import suite, tracegen
    failures = 0
    cfgs = [eng.VectorEngineConfig(mvl=64, lanes=4),
            eng.VectorEngineConfig(mvl=256, lanes=8, ooo_issue=True,
                                   interconnect="crossbar")]
    apps = sorted(tracegen.APPS)

    # 1) event-sum identity + bitwise default, all 10 apps x config sample
    worst = 0.0
    for app in apps:
        for cfg in cfgs:
            body = tracegen.body_for(app, suite.effective_mvl(app, cfg), cfg)
            tr = body.tile(6)
            base = eng.simulate(tr, cfg)
            prof = eng.simulate(tr, cfg, collect_stats=True)
            for k, v in base.items():
                if prof[k] != v:
                    print(f"FAIL bitwise: {app} {cfg.label()} {k}: "
                          f"{v} != {prof[k]}")
                    failures += 1
            rel = abs(sum(prof["stalls"].values()) - prof["time"]) \
                / max(prof["time"], 1.0)
            worst = max(worst, rel)
            if rel > 1e-4:
                print(f"FAIL identity: {app} {cfg.label()} rel_err={rel:.2e}")
                failures += 1
    print(f"identity: 10 apps x {len(cfgs)} cfgs, worst rel err {worst:.2e}")

    # 2) timeline: valid JSON with the required Chrome-trace keys
    cfg = cfgs[0]
    body = tracegen.body_for("blackscholes",
                             suite.effective_mvl("blackscholes", cfg), cfg)
    doc = json.loads(json.dumps(chrome_trace(body.tile(2), cfg,
                                             label="blackscholes")))
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    ok = (bool(spans)
          and all({"name", "ts", "dur", "pid", "tid"} <= set(e) for e in spans)
          and all(math.isfinite(e["ts"]) and e["dur"] >= 0 for e in spans)
          and doc["otherData"]["schema"] == SCHEMA)
    if not ok:
        print("FAIL timeline: invalid Chrome-trace document")
        failures += 1
    print(f"timeline: {len(spans)} spans, valid JSON")

    # 3) histogram percentile sanity
    h = LatencyHistogram()
    for ms in range(1, 101):
        h.add(ms / 1e3)
    p50, p99 = h.percentile(0.5), h.percentile(0.99)
    if not (0.03 < p50 < 0.08 and 0.08 < p99 <= 0.11 and h.count == 100):
        print(f"FAIL histogram: p50={p50} p99={p99} n={h.count}")
        failures += 1
    print(f"histogram: n={h.count} p50={p50 * 1e3:.1f}ms p99={p99 * 1e3:.1f}ms")
    return failures


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="attribution identity + bitwise default + timeline")
    p.add_argument("--scorecard", action="store_true",
                   help="print the 10-app module-stress scorecard")
    p.add_argument("--timeline", metavar="APP",
                   help="write a Chrome-trace timeline for one app")
    p.add_argument("-o", "--out", default="timeline.json")
    p.add_argument("--mvl", type=int, default=64)
    p.add_argument("--lanes", type=int, default=4)
    args = p.parse_args(argv)
    rc = 0
    if args.smoke:
        rc = _smoke()
        print("profile-smoke:", "PASS" if rc == 0 else f"{rc} failure(s)")
    if args.scorecard:
        print(scorecard().table())
    if args.timeline:
        from repro.core import suite, tracegen
        cfg = eng.VectorEngineConfig(mvl=args.mvl, lanes=args.lanes)
        body = tracegen.body_for(
            args.timeline, suite.effective_mvl(args.timeline, cfg), cfg)
        doc = write_chrome_trace(args.out, body.tile(2), cfg,
                                 label=args.timeline)
        print(f"wrote {args.out}: {len(doc['traceEvents'])} events, "
              f"{doc['otherData']['time']:.1f} cycles")
    return 0 if rc == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
