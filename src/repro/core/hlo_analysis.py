"""HLO-text analysis: per-device FLOPs / HBM bytes / ICI bytes for the roofline.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits a ``while``
body ONCE — a model whose layers are driven by ``lax.scan`` under-reports
FLOPs/bytes by a factor of num_layers (verified empirically: qwen2.5-3b
train_4k reported 8x fewer FLOPs than 6ND).  This module re-derives the three
roofline inputs from ``compiled.as_text()`` with loop trip counts applied:

  * FLOPs: 2*|out|*K for dots (K = contracted extent), |out| for elementwise,
    |in| for reductions; fusion bodies are recursed into; while bodies are
    multiplied by the trip count recovered from the loop condition constant.
  * HBM bytes: operand + result bytes at memory-boundary instructions
    (fusion/dot/copy/dus/gather/... at computation top level; fusion-internal
    ops live in registers/VMEM and are not counted).
  * ICI bytes: ring-model cost per collective (all-reduce 2x(n-1)/n, etc.).

This is a structural model of the partitioned program, not a wall-clock
measurement — exactly the artifact the dry-run methodology calls for.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "compare",
    "select", "and", "or", "xor", "not", "convert", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "exponential-minus-one", "log-plus-one",
    "atan2", "erf", "round-nearest-even", "round-nearest-afz", "clamp",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "is-finite", "cbrt", "tan",
}

MEMORY_OPS = {
    "fusion", "dot", "custom-call", "copy", "concatenate",
    "dynamic-update-slice", "dynamic-slice", "slice", "gather", "scatter",
    "reduce", "transpose", "broadcast", "reshape", "pad", "reverse",
    "convolution", "sort", "iota", "reduce-window", "select-and-scatter",
    "convert", "add", "multiply",  # top-level (unfused) elementwise still reads/writes HBM
} | set(ELEMENTWISE) | set(COLLECTIVES)

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")


def _one_shape(text: str):
    """Parse the first array shape token -> (elements, bytes). Tuples: sum."""
    elems = 0
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclass
class Instr:
    name: str
    op: str
    shape_str: str        # result shape text (may be a tuple)
    operands: list        # operand %names
    attrs: str            # rest of the line
    elems: int = 0
    bytes: int = 0


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    table: dict = field(default_factory=dict)   # %name -> Instr
    trip_const: int = 1


_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_OP_CALL = re.compile(r"([\w\-]+)\((.*)$", re.DOTALL)


def _split_instr(line: str):
    """'ROOT %n = <shape> op(operands), attrs' -> (name, shape, op, rest)|None.

    Tuple result shapes contain `/*index=N*/` comments (with '=') and nested
    parens, so the shape is extracted with a paren scan, not a regex.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):  # tuple shape
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape_str, tail = rest[:end + 1], rest[end + 1:]
    else:
        m = re.match(r"([\w\[\],\{\}\*]+)\s+", rest)
        if not m:
            return None
        shape_str, tail = m.group(1), rest[m.end():]
    m = _OP_CALL.match(tail.strip())
    if not m:
        return None
    return name, shape_str, m.group(1), m.group(2)


def parse_hlo(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line.strip():
            continue
        if not line.startswith(" ") and line.strip().endswith("{") and "(" in line:
            m = _HDR.match(line.strip())
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if cur is None or line.strip() == "}":
            continue
        parsed = _split_instr(line)
        if not parsed:
            continue
        name, shape_str, op, rest = parsed
        # split operands from attrs: operands run until the matching ')'
        depth = 1
        idx = 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:idx], rest[idx + 1:]
        ins = Instr(name, op, shape_str, _OPERAND.findall(operand_str), attrs)
        ins.elems, ins.bytes = _one_shape(shape_str)
        cur.instrs.append(ins)
        cur.table[name] = ins
        if op == "constant":
            cm = _CONST_INT.search(line)
            if cm:
                cur.trip_const = max(cur.trip_const, int(cm.group(1)))
    return comps, entry


def _trip_count(comps, cond_name, depth=0) -> int:
    """Max integer constant reachable from the loop condition computation.

    jax lowers ``lax.scan`` to a while whose condition compares the induction
    variable against a constant; after optimization the compare may live in a
    fusion called from the condition, so we recurse through callees.
    """
    c = comps.get(cond_name)
    if c is None or depth > 8:
        return 1
    best = c.trip_const
    for ins in c.instrs:
        for callee in re.findall(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.attrs):
            best = max(best, _trip_count(comps, callee, depth + 1))
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    lhs = comp.table.get(ins.operands[0]) if ins.operands else None
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    if lhs is not None and m and m.group(1):
        dims_m = _SHAPE_TOKEN.search(lhs.shape_str)
        if dims_m and dims_m.group(2):
            dims = [int(d) for d in dims_m.group(2).split(",")]
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    k *= dims[ci]
    return 2.0 * ins.elems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    rhs = comp.table.get(ins.operands[1]) if len(ins.operands) > 1 else None
    if rhs is None:
        return 2.0 * ins.elems
    km = _SHAPE_TOKEN.search(rhs.shape_str)
    kelems = 1
    if km and km.group(2):
        for d in km.group(2).split(","):
            kelems *= int(d)
    out_feat = 1
    om = _SHAPE_TOKEN.search(ins.shape_str)
    if om and om.group(2):
        out_feat = int(om.group(2).split(",")[-1])
    return 2.0 * ins.elems * max(kelems // max(out_feat, 1), 1)


_RING_COLLS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all"}


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    return 2


def _ici_bytes(op, payload, operand, gsize) -> float:
    frac = (gsize - 1) / max(gsize, 1)
    if op == "all-reduce":
        return 2.0 * payload * frac
    if op == "all-gather":
        return payload * frac
    if op == "reduce-scatter":
        return max(payload, operand) * frac
    if op in ("all-to-all", "ragged-all-to-all"):
        return payload * frac
    if op == "collective-permute":
        return float(payload)
    return float(payload)


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    by_op: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.ici_bytes += other.ici_bytes * mult
        for k, v in other.by_op.items():
            self.by_op[k] = self.by_op.get(k, 0.0) + v * mult


def analyze(text: str) -> dict:
    comps, entry = parse_hlo(text)
    memo: dict[tuple, Cost] = {}

    def operand_bytes(ins: Instr, comp: Computation) -> int:
        total = 0
        for o in ins.operands:
            t = comp.table.get(o)
            if t is not None:
                total += t.bytes
        return total

    def operand_elems(ins: Instr, comp: Computation) -> int:
        total = 0
        for o in ins.operands:
            t = comp.table.get(o)
            if t is not None:
                total += t.elems
        return total

    # Ops that read only a result-sized window of their (possibly huge) first
    # operand: a dynamic-slice of the [L, ...] stacked scan weights reads one
    # layer's slice, not the whole stack; a vocab-table gather reads |result|.
    _SLICING = {"dynamic-slice", "gather", "slice"}

    def fusion_operand_bytes(ins: Instr, comp: Computation, callee: Computation) -> int:
        """Operand bytes for a fusion, crediting slice-only-consumed params."""
        params = {}
        for fi in callee.instrs:
            if fi.op == "parameter":
                m = re.match(r"(\d+)", fi.attrs)
                if m:
                    params[int(m.group(1))] = fi.name
        total = 0
        for i, o in enumerate(ins.operands):
            t = comp.table.get(o)
            if t is None:
                continue
            pname = params.get(i)
            if pname is not None:
                users = [fi for fi in callee.instrs if pname in fi.operands]
                if users and all(u.op in _SLICING and u.operands
                                 and u.operands[0] == pname for u in users):
                    total += sum(u.bytes for u in users)
                    continue
            total += t.bytes
        return total

    def instr_hbm_bytes(ins: Instr, comp: Computation) -> int:
        if ins.op in _SLICING:
            # read a result-sized window (+ indices, negligible) + write result
            return 2 * ins.bytes
        if ins.op in ("dynamic-update-slice", "scatter"):
            upd = comp.table.get(ins.operands[1]) if len(ins.operands) > 1 else None
            w = upd.bytes if upd is not None else ins.bytes
            return 2 * w  # read update + write window (buffer itself is aliased)
        return ins.bytes + operand_bytes(ins, comp)

    def walk(name: str, inside_fusion: bool, depth=0) -> Cost:
        key = (name, inside_fusion)
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # cycle guard
        comp = comps.get(name)
        out = Cost()
        if comp is None or depth > 64:
            return out
        for ins in comp.instrs:
            op = ins.op
            base = op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                b = _ici_bytes(base, ins.bytes, operand_bytes(ins, comp),
                               _group_size(ins.attrs))
                out.ici_bytes += b
                out.by_op[base] = out.by_op.get(base, 0.0) + b
                if not inside_fusion:
                    out.hbm_bytes += ins.bytes + operand_bytes(ins, comp)
                continue
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                if bm:
                    trip = _trip_count(comps, cm.group(1)) if cm else 1
                    out.add(walk(bm.group(1), inside_fusion, depth + 1), trip)
                continue
            if op in ("call", "conditional", "async-start"):
                for callee in re.findall(
                        r"(?:to_apply|body|branch_computations=\{|called_computations=\{)%?([\w\.\-]+)",
                        ins.attrs):
                    out.add(walk(callee, inside_fusion, depth + 1))
                continue
            if op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                callee = comps.get(m.group(1)) if m else None
                if callee is not None:
                    out.add(walk(callee.name, True, depth + 1))
                if not inside_fusion:
                    if callee is not None:
                        out.hbm_bytes += ins.bytes + fusion_operand_bytes(ins, comp, callee)
                    else:
                        out.hbm_bytes += ins.bytes + operand_bytes(ins, comp)
                continue
            # plain instruction
            if op == "dot":
                out.flops += _dot_flops(ins, comp)
            elif op == "convolution":
                out.flops += _conv_flops(ins, comp)
            elif op in ELEMENTWISE:
                out.flops += ins.elems
            elif op in ("reduce", "reduce-window"):
                out.flops += max(operand_elems(ins, comp), ins.elems)
            if (not inside_fusion) and op in MEMORY_OPS:
                out.hbm_bytes += instr_hbm_bytes(ins, comp)
        memo[key] = out
        return out

    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].instrs), default=None)
    total = walk(entry, False) if entry else Cost()
    n_colls = sum(
        1 for c in comps.values() for i in c.instrs
        if i.op.replace("-start", "").replace("-done", "") in COLLECTIVES
        and not i.op.endswith("-done"))
    return {
        "flops": total.flops,
        "hbm_bytes": total.hbm_bytes,
        "ici_bytes": total.ici_bytes,
        "by_op": total.by_op,
        "static_collective_count": n_colls,
    }


def collective_stats(text: str) -> dict:
    a = analyze(text)
    return {"ici_bytes": a["ici_bytes"], "by_op": a["by_op"],
            "static_collective_count": a["static_collective_count"]}
