"""Event-based dual-issue in-order scalar-pipeline model (paper §3.1).

The paper's speedup baseline is a real 2 GHz dual-issue in-order scalar core
measured in gem5.  This module models it the way cycle-approximate perf
models score real cores — per-instruction-class *events* — and retires the
per-app ``SCALAR_BASELINE_MULT`` magic multipliers that used to stand in for
it (one of which, particlefilter's 0.104, was documented as non-physical).

The dynamic instruction stream of an app's scalar ROI is summarized into six
class segments (simple / mul / div / trans / load / branch) from the app's
published instruction counts, FU-class mix and its ``ScalarProfile``
(``tracegen.SCALAR_PROFILES``).  A ``lax.scan`` folds the segments into the
per-event-kind cycle and count accumulators:

  * ``issue``  — issue slots consumed (1/issue_width per instruction;
                 macro-op fusion removes one slot per fused pair)
  * ``raw``    — RAW-dependence stalls: a consumer waits the producer's
                 remaining latency, ``raw_frac x (lat - 1)`` per instruction
  * ``struct`` — structural stalls on the unpipelined divider
  * ``bmiss`` / ``bhit`` — branch events; each miss costs
                 ``branch_miss_penalty`` cycles
  * ``mem``    — scalar load stalls beyond the pipelined L1 hit
                 (``mem_stall_cyc`` per load, the fitted profile parameter)

Everything configuration-dependent (``issue_width``, ``branch_miss_penalty``,
``fusion``, the scalar clock) is a traced parameter, so one compiled scan
serves every core and the model vmaps over a config axis exactly like the
vector engine (``scalar_runtime_ns_batch`` is bitwise-equal to the
sequential path).  The jit key is the (6, 8) segment shape — shared by every
app — so sweeps never recompile.

>>> from repro.core import engine as eng
>>> t2 = scalar_runtime_ns("pathfinder")                  # default dual-issue
>>> t1 = scalar_runtime_ns("pathfinder",
...                        eng.VectorEngineConfig(issue_width=1))
>>> t1 > t2
True
>>> ev = scalar_events("pathfinder")
>>> ev["bhit"] > ev["bmiss"] > 0
True

Accuracy is pinned by the anchor scorecard: ``python -m
repro.core.scalar_pipeline --check`` verifies all 11 paper §5 anchors plus
batched-vs-sequential bitwise equivalence (the scripts/ci.sh
``scalar-scorecard`` gate); ``benchmarks/calibrate.py --scorecard`` prints
the per-anchor relative errors and the residual-error budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tracegen

# Event kinds, in accumulator order (cva6 perf-model style: issue / hazard /
# branch events scored per instruction class).
EVENT_KINDS = ("issue", "raw", "struct", "bmiss", "bhit", "mem", "fused")

# Segment rows, fixed order; every app shares this (6, N_COLS) shape.
SEG_CLASSES = ("simple", "mul", "div", "trans", "load", "branch")

# FIXED architectural latencies (scalar-core cycles; not fitted): fully
# bypassed ALU, pipelined 4-cycle FP-MAC and L1 hit, 20-cycle unpipelined
# divide, 24-cycle transcendental sequence.  docs/calibration.md documents
# fitted-vs-fixed in full.
OP_LATENCY = np.array([1.0, 4.0, 20.0, 24.0, 4.0, 1.0], np.float32)

# FIXED: back-to-back occupancy rate of the single unpipelined divider
# (structural-hazard events beyond the RAW stalls already counted).
DIV_STRUCT_RATE = 0.25

# segment feature columns
_COLS = ("count", "lat", "raw_frac", "fusible", "bmiss_rate", "mem_stall",
         "is_branch", "struct_frac")
N_COLS = len(_COLS)


def segments_for(app_name: str) -> np.ndarray:
    """The (6, 8) event-segment array of one app's scalar-version ROI.

    Row counts decompose ``counts.scalar_code_total`` (scaled by the
    profile's ``roi_instr_fraction``): branches and loads per the profile
    fractions, FP work per the app's FU-class mix over its element-op total,
    the remainder simple-class ALU.
    """
    app = tracegen.app_for(app_name)
    prof = tracegen.scalar_profile_for(app_name)
    counts = app.counts(8)               # element ops at MVL=8 (min overhead)
    n = counts.scalar_code_total * prof.roi_instr_fraction
    work = counts.vector_ops * prof.roi_instr_fraction
    n_branch = prof.branch_frac * n
    n_load = prof.load_frac * n
    n_mul = work * app.mix.get("mul", 0.0)
    n_div = work * app.mix.get("div", 0.0)
    n_trans = work * app.mix.get("trans", 0.0)
    n_simple = max(n - n_branch - n_load - n_mul - n_div - n_trans, 0.0)
    seg = np.zeros((len(SEG_CLASSES), N_COLS), np.float32)
    seg[:, 0] = (n_simple, n_mul, n_div, n_trans, n_load, n_branch)
    seg[:, 1] = OP_LATENCY
    seg[:, 2] = prof.raw_frac
    seg[0, 3] = prof.fusible_frac        # fusion pairs are simple-class
    seg[5, 4] = prof.branch_miss_rate
    seg[4, 5] = prof.mem_stall_cyc
    seg[5, 6] = 1.0
    seg[2, 7] = DIV_STRUCT_RATE
    return seg


def cfg_scalar_params(cfg=None) -> tuple:
    """The scalar-core parameter vector ``(issue_width, branch_miss_penalty,
    fusion, scalar_freq_ghz)`` of a config (np scalars, stackable for the
    batch axis); ``None`` selects the Table-10 default core."""
    if cfg is None:
        from repro.core import engine as eng
        cfg = eng.VectorEngineConfig()
    return (np.float32(cfg.issue_width), np.float32(cfg.branch_miss_penalty),
            np.float32(1.0 if cfg.fusion else 0.0),
            np.float32(cfg.scalar_freq_ghz))


def _scan_core(seg, params):
    """Fold the segment events into (total cycles, per-kind accumulators)."""
    issue_w, bmp, fusion_f, _freq = params

    def step(carry, row):
        cyc, ev = carry
        count, lat, raw, fusible, bmr, mem, is_br, struct = (
            row[0], row[1], row[2], row[3], row[4], row[5], row[6], row[7])
        fused = count * fusible * fusion_f        # fused pairs: 1 slot each
        slots = (count - fused) / issue_w
        stall_lat = jnp.maximum(lat - 1.0, 0.0)
        raw_st = count * raw * stall_lat
        struct_st = count * struct * stall_lat
        n_miss = count * bmr
        bmiss_st = n_miss * bmp
        n_hit = count * is_br - n_miss
        mem_st = count * mem
        cyc = cyc + slots + raw_st + struct_st + bmiss_st + mem_st
        ev = ev + jnp.stack([slots, raw_st, struct_st, n_miss, n_hit,
                             mem_st, fused])
        return (cyc, ev), None

    init = (jnp.float32(0.0), jnp.zeros(len(EVENT_KINDS), jnp.float32))
    (cyc, ev), _ = jax.lax.scan(step, init, seg)
    return cyc, ev


_pipeline_jit = jax.jit(_scan_core)
_pipeline_batch_jit = jax.jit(jax.vmap(_scan_core))


def scalar_cycles(app_name: str, cfg=None) -> float:
    """Total modeled scalar-core cycles of the app's scalar-version ROI."""
    cyc, _ = _pipeline_jit(jnp.asarray(segments_for(app_name)),
                           tuple(jnp.asarray(p)
                                 for p in cfg_scalar_params(cfg)))
    return float(cyc)


def scalar_events(app_name: str, cfg=None) -> dict:
    """Per-event-kind accumulators (cycles for stall kinds, counts for
    ``bmiss``/``bhit``/``fused``) — the scorecard's breakdown view."""
    _, ev = _pipeline_jit(jnp.asarray(segments_for(app_name)),
                          tuple(jnp.asarray(p)
                                for p in cfg_scalar_params(cfg)))
    return dict(zip(EVENT_KINDS, (float(v) for v in ev)))


@functools.lru_cache(maxsize=None)
def _runtime_cached(base_app: str, params: tuple) -> float:
    cyc, _ = _pipeline_jit(jnp.asarray(segments_for(base_app)),
                           tuple(jnp.asarray(p) for p in params))
    return float(cyc) / float(params[3])


def scalar_runtime_ns(app_name: str, cfg=None) -> float:
    """Modeled scalar-version runtime (ns) on the config's scalar core
    (``None``: the default 2 GHz dual-issue core).  Memoized per
    (base app, scalar-core knobs): trace-source variants (``"<app>:asm"``)
    share the base app's scalar code, and sweeps over vector-side knobs all
    hit one cache entry."""
    base = tracegen.split_variant(app_name)[0]
    return _runtime_cached(base, cfg_scalar_params(cfg))


def scalar_runtime_ns_batch(apps, cfgs) -> list[float]:
    """Batched ``scalar_runtime_ns``: N (app, config) pairs through one
    vmapped scan dispatch.  Bitwise-equal to the sequential path (the scan
    core is shared; ``--check`` asserts it)."""
    if len(apps) != len(cfgs):
        raise ValueError(f"{len(apps)} apps vs {len(cfgs)} configs")
    if not apps:
        return []
    segs = jnp.asarray(np.stack([segments_for(a) for a in apps]))
    cols = list(zip(*(cfg_scalar_params(c) for c in cfgs)))
    params = tuple(jnp.asarray(np.stack(col)) for col in cols)
    cyc, _ = _pipeline_batch_jit(segs, params)
    freqs = np.asarray(cols[3], np.float32)
    return [float(c) / float(f) for c, f in zip(np.asarray(cyc), freqs)]


# --------------------------------------------------------------------------
# --check: the CI scalar-scorecard gate
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse

    from repro.core import engine as eng
    from repro.core import suite

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="verify the §5 anchors, batched-vs-sequential "
                         "bitwise equivalence and knob monotonicity "
                         "(the scripts/ci.sh scalar-scorecard gate)")
    args = ap.parse_args(argv)
    if not args.check:
        ap.print_help()
        return 0

    failures = []
    # 1. all 11 paper §5 anchors within the documented tolerance
    from repro.core.anchors import ANCHORS, EQ_LO, EQ_HI, LT_SLACK
    print("== anchors ==")
    for app, mvl, lanes, target, kind in ANCHORS:
        cfg = eng.VectorEngineConfig(mvl=mvl, lanes=lanes)
        got = suite.speedup(app, cfg)
        if kind == "eq":
            ok = EQ_LO <= got / target <= EQ_HI
        else:
            ok = got <= target * LT_SLACK
        mark = "ok" if ok else "MISS"
        print(f"  {app:16s} mvl={mvl:3d} L={lanes} model={got:5.2f} "
              f"paper={target:5.2f} [{kind}] {mark}")
        if not ok:
            failures.append(f"anchor {app}@{mvl}x{lanes}")

    # 2. batched == sequential, bitwise
    apps = sorted(tracegen.APPS)
    cfgs = [eng.VectorEngineConfig(issue_width=1 + i % 3,
                                   branch_miss_penalty=float(4 + 2 * (i % 4)),
                                   fusion=bool(i % 2))
            for i in range(len(apps))]
    batched = scalar_runtime_ns_batch(apps, cfgs)
    seq = [scalar_runtime_ns(a, c) for a, c in zip(apps, cfgs)]
    if batched == seq:
        print("== batched-vs-sequential: bitwise-equal "
              f"({len(apps)} pairs) ==")
    else:
        failures.append("batched != sequential")

    # 3. knob monotonicity + physical-CPI floor on every app
    for a in apps:
        t1 = scalar_runtime_ns(a, eng.VectorEngineConfig(issue_width=1))
        t2 = scalar_runtime_ns(a)
        t4 = scalar_runtime_ns(a, eng.VectorEngineConfig(issue_width=4))
        bp = scalar_runtime_ns(
            a, eng.VectorEngineConfig(branch_miss_penalty=20.0))
        fu = scalar_runtime_ns(a, eng.VectorEngineConfig(fusion=True))
        if not (t1 > t2 >= t4 and bp > t2 and fu < t2):
            failures.append(f"monotonicity {a}")
        prof = tracegen.scalar_profile_for(a)
        counts = tracegen.app_for(a).counts(8)
        n_roi = counts.scalar_code_total * prof.roi_instr_fraction
        cpi = scalar_cycles(a) / n_roi
        if cpi < 0.5:
            failures.append(f"non-physical CPI {a}: {cpi:.3f}")
    if not any(f.startswith(("monotonicity", "non-physical"))
               for f in failures):
        print("== knob monotonicity + CPI floor: ok "
              f"({len(apps)} apps) ==")

    if failures:
        print("FAILURES:", ", ".join(failures))
        return 1
    print("scalar-scorecard: PASS")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
