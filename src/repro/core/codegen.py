"""RVV v1.0 code generator: jaxpr-lowered kernels back out as real assembly.

The inverse of ``repro.core.rvv``: any kernel the jaxpr frontend
(``repro.core.frontend``) accepts is emitted as GNU-``as`` RVV v1.0 assembly
— ``vsetvli`` strip-mine structure with exact fractional trip counts,
``.chunk``/``.stream`` directives carrying the chunk count and stream
footprints into the memory model, and every IR construct spelled with the
instruction the decoder maps back to the identical record:

==============================  ===========================================
vector IR record                emitted RVV v1.0
==============================  ===========================================
``VARITH`` @ SIMPLE/MUL/DIV     ``vfadd/vfmul/vfdiv`` ``.vv``/``.vf`` by
                                operand count (``vid.v`` for a 0-source
                                SIMPLE op)
``VARITH`` @ TRANS              ``vfexp.v`` / ``vfpow.vv`` pseudo-calls
``VREDUCE``                     ``vfredusum.vs``
``VSLIDE``                      ``vslide1down.vx``
``VMASK_SCALAR``                ``vcpop.m``
``VMOVE``                       ``vmv.v.v`` at VL, ``vmv<n>r.v`` for
                                whole-register (``n x cfg.mvl``-element)
                                spill moves, ``vmv.v.i`` for splats
``VLOAD``/``VSTORE``            ``vle64/vlse64/vluxei64`` (+ store forms),
                                address registers ``la``-bound to
                                ``.stream`` footprint symbols
``SCALAR_BLOCK``                ``.rept`` filler over untracked registers
                                (``add``/``mul``/``div`` by FU class; a
                                ``dep_scalar`` block reads the hot
                                ``vcpop.m`` result)
==============================  ===========================================

Because one ``.s`` file must decode correctly at *every* hardware MVL, the
emitted kernel opens with ``vsetvli t0, zero`` (``t0`` = VLMAX = the
effective MVL) and dispatches on the known ``t0`` to a per-VL body — the
decoder executes known-value branches, so exactly one body is decoded per
configuration and an un-dispatched VL falls into a loud ``call abort``.
The single ``.chunk`` loop closes on a ``bgtz`` counter whose initial value
and step are the exact ``float.as_integer_ratio`` of the app's fractional
chunk count, so the decoder-derived trip count is *bitwise* the closed form.

The correctness contract is the round trip (``crossval.round_trip_all``,
the ci.sh ``codegen-roundtrip`` gate, ``python -m repro.core.codegen
--check-all``): for every app carrying a ``kernel=`` spec and every MVL in
``rvv.CHECK_MVLS``, ``rvv.decode(emit_app(app))`` must fingerprint-equal
the direct jaxpr lowering, reproduce its chunk count bitwise, and pass
``isa.validate_trace``.

>>> from repro.core import codegen, frontend, isa, rvv
>>> spec = lambda vl, cfg: [frontend.KernelBody(
...     fn=lambda x, y: x * 2.0 + y, vl=vl,
...     ins=(frontend.Stream("x", 32.0), frontend.Stream("y", 32.0)),
...     outs=(frontend.Stream("out", 32.0),))]
>>> text = codegen.emit_kernel(spec, "saxpy", avl=4096, mvls=(8, 64))
>>> d = rvv.decode(text, 64)
>>> d.trace.vl.tolist()
[64, 64, 64, 64, 64]
>>> isa.trace_fingerprint(d.trace) == isa.trace_fingerprint(
...     frontend.lower(spec(64, None)).trace)
True
>>> d.chunks        # 4096 elements strip-mined at VL=64
64.0
"""
from __future__ import annotations

import re

from repro.core import isa

_S, _M, _D, _T = isa.FU_SIMPLE, isa.FU_MUL, isa.FU_DIV, isa.FU_TRANS


class CodegenError(Exception):
    """The trace uses a record shape no RVV spelling decodes back to
    (loud, like ``frontend.FrontendError`` / ``rvv.RvvError``)."""


# scalar-register conventions of the emitted kernels (disjoint by role, so
# the decoder's abstract machine never confuses bookkeeping with work):
#   t0      VLMAX probe / per-VL dispatch key      (known value)
#   t1      dispatch comparand                     (known value)
#   t2      vsetvli AVL staging                    (known value)
#   t3      stride operand of vlse/vsse            (untracked, never read)
#   t5      scalar operand of vslide1down.vx       (untracked, never read)
#   t6      vcpop.m destination                    (hot, never read)
#   a3/a4   chunk counter / step                   (known values)
#   a5      stream address staging (la-bound)      (symbol value)
#   s3      hot scalar seed (prologue vcpop.m)     (read by dep blocks)
#   s4      dep-block sink                         (hot, never read)
#   s5/s6   plain scalar-filler registers          (untracked, never hot)

_SCALAR_SPELL = {
    (False, _S): "add s5, s5, s6",
    (False, _M): "mul s5, s5, s6",
    (False, _D): "div s5, s5, s6",
    (True, _S): "add s4, s5, s3",
    (True, _M): "mul s4, s5, s3",
    (True, _D): "div s4, s5, s3",
}

_ARITH_VV = {_S: "vfadd.vv", _M: "vfmul.vv", _D: "vfdiv.vv", _T: "vfpow.vv"}
_ARITH_VF = {_S: "vfadd.vf", _M: "vfmul.vf", _D: "vfdiv.vf"}

_LOAD_OP = {isa.MEM_UNIT: "vle64.v", isa.MEM_STRIDED: "vlse64.v",
            isa.MEM_INDEXED: "vluxei64.v"}
_STORE_OP = {isa.MEM_UNIT: "vse64.v", isa.MEM_STRIDED: "vsse64.v",
             isa.MEM_INDEXED: "vsuxei64.v"}


def _vector_reads(rec: dict) -> list[int]:
    """Registers the decoder's def-before-use check reads for this record."""
    k, n = rec["kind"], rec["n_src"]
    out = []
    if k == isa.VARITH:
        if n >= 1 and rec["src1"] >= 0:
            out.append(rec["src1"])
        if n >= 2 and rec["src2"] >= 0:
            out.append(rec["src2"])
    elif k == isa.VLOAD:
        if n >= 1 and rec["src1"] >= 0:
            out.append(rec["src1"])
    elif k == isa.VSTORE:
        if rec["src1"] >= 0:
            out.append(rec["src1"])
        if n >= 2 and rec["src2"] >= 0:
            out.append(rec["src2"])
    elif k in (isa.VSLIDE, isa.VREDUCE, isa.VMASK_SCALAR, isa.VMOVE):
        if rec["src1"] >= 0 and n >= 1:
            out.append(rec["src1"])
    return out


def _predefined_regs(recs: list[dict]) -> set[int]:
    """Vector registers a body reads before its first write — the emitter
    initializes these in the prologue (cf. ``Decoded.prologue_defs``)."""
    written: set[int] = set()
    need: set[int] = set()
    for rec in recs:
        if rec["kind"] == isa.SCALAR_BLOCK:
            continue
        for r in _vector_reads(rec):
            if r not in written:
                need.add(r)
        if rec["dst"] >= 0:
            written.add(rec["dst"])
    return need


def _index_regs(recs: list[dict]) -> set[int]:
    """Index-vector registers of indexed loads/stores (spelled ``vid.v``
    in the prologue instead of a zero splat)."""
    out: set[int] = set()
    for rec in recs:
        if rec["mem_pattern"] != isa.MEM_INDEXED:
            continue
        if rec["kind"] == isa.VLOAD and rec["src1"] >= 0:
            out.add(rec["src1"])
        elif rec["kind"] == isa.VSTORE and rec["src2"] >= 0:
            out.add(rec["src2"])
    return out


class _Emitter:
    """Emission state for one kernel: lines, stream-symbol pool, VL."""

    def __init__(self):
        self.lines: list[str] = []
        self.syms: dict[str, str] = {}    # repr(footprint) -> symbol

    def op(self, text: str):
        self.lines.append(f"    {text}")

    def label(self, name: str):
        self.lines.append(f"{name}:")

    def sym_of(self, footprint_kb: float) -> str:
        key = repr(float(footprint_kb))
        sym = self.syms.get(key)
        if sym is None:
            sym = self.syms[key] = f"fp{len(self.syms)}"
        return sym


def _emit_body(e: _Emitter, recs: list[dict], eff: int, whole: int):
    """Emit one per-VL chunk body; entry VL is ``eff`` (the prologue's
    ``vsetvli t0, zero`` result)."""
    vl = eff
    prev_scalar_fu = None

    def ensure_vl(want: int, rec: dict):
        nonlocal vl
        if want > eff:
            raise CodegenError(
                f"record {rec} needs VL={want} > VLMAX={eff}; only "
                "whole-register moves may exceed the effective MVL")
        if want != vl:
            e.op(f"li t2, {want}")
            e.op("vsetvli zero, t2, e64, m1")
            vl = want

    for rec in recs:
        k = rec["kind"]
        if k == isa.SCALAR_BLOCK:
            fu, count, dep = rec["fu"], rec["scalar_count"], rec["dep_scalar"]
            if count < 1:
                raise CodegenError(f"empty SCALAR_BLOCK (count={count})")
            if fu == prev_scalar_fu:
                raise CodegenError(
                    "adjacent same-FU scalar blocks would coalesce into one "
                    "on decode and cannot round-trip")
            spell = _SCALAR_SPELL.get((dep, fu))
            if spell is None:
                raise CodegenError(
                    f"no scalar spelling for FU class {fu} (RISC-V has no "
                    "scalar transcendental instruction)")
            prev_scalar_fu = fu
            e.op(f".rept {count}")
            e.op(spell)
            e.op(".endr")
            continue
        prev_scalar_fu = None

        if k == isa.VARITH:
            fu, n = rec["fu"], rec["n_src"]
            d, a, b = rec["dst"], rec["src1"], rec["src2"]
            if d < 0 or n > 2 or (n >= 1 and a < 0) or (n >= 2 and b < 0):
                raise CodegenError(f"unencodable VARITH record {rec}")
            ensure_vl(rec["vl"], rec)
            if n == 2:
                e.op(f"{_ARITH_VV[fu]} v{d}, v{a}, v{b}")
            elif n == 1:
                if fu == _T:
                    e.op(f"vfexp.v v{d}, v{a}")
                else:
                    e.op(f"{_ARITH_VF[fu]} v{d}, v{a}, ft0")
            else:
                if fu == _S:
                    e.op(f"vid.v v{d}")
                elif fu == _T:
                    e.op(f"vfexp.v v{d}, ft0")
                else:
                    e.op(f"{_ARITH_VF[fu]} v{d}, ft0, ft1")
        elif k in (isa.VLOAD, isa.VSTORE):
            pat, n = rec["mem_pattern"], rec["n_src"]
            ensure_vl(rec["vl"], rec)
            e.op(f"la a5, {e.sym_of(rec['footprint_kb'])}")
            if k == isa.VLOAD:
                d = rec["dst"]
                if d < 0:
                    raise CodegenError(f"VLOAD without destination: {rec}")
                if pat == isa.MEM_INDEXED:
                    if n != 1 or rec["src1"] < 0:
                        raise CodegenError(
                            f"indexed VLOAD needs n_src=1 + an index "
                            f"register: {rec}")
                    e.op(f"vluxei64.v v{d}, (a5), v{rec['src1']}")
                elif n != 0:
                    raise CodegenError(f"{_LOAD_OP[pat]} decodes to "
                                       f"n_src=0, record has {n}: {rec}")
                elif pat == isa.MEM_STRIDED:
                    e.op(f"vlse64.v v{d}, (a5), t3")
                else:
                    e.op(f"vle64.v v{d}, (a5)")
            else:
                s = rec["src1"]
                if s < 0:
                    raise CodegenError(f"VSTORE without source: {rec}")
                if pat == isa.MEM_INDEXED:
                    if n != 2 or rec["src2"] < 0:
                        raise CodegenError(
                            f"indexed VSTORE needs n_src=2 + an index "
                            f"register: {rec}")
                    e.op(f"vsuxei64.v v{s}, (a5), v{rec['src2']}")
                elif n != 1:
                    raise CodegenError(f"{_STORE_OP[pat]} decodes to "
                                       f"n_src=1, record has {n}: {rec}")
                elif pat == isa.MEM_STRIDED:
                    e.op(f"vsse64.v v{s}, (a5), t3")
                else:
                    e.op(f"vse64.v v{s}, (a5)")
        elif k == isa.VSLIDE:
            if rec["dst"] < 0 or rec["src1"] < 0 or rec["n_src"] != 1:
                raise CodegenError(f"unencodable VSLIDE record {rec}")
            ensure_vl(rec["vl"], rec)
            e.op(f"vslide1down.vx v{rec['dst']}, v{rec['src1']}, t5")
        elif k == isa.VREDUCE:
            if rec["fu"] != _S:
                raise CodegenError(
                    f"VREDUCE at FU class {rec['fu']} cannot round-trip: "
                    "RVV vred* always decodes to FU_SIMPLE")
            if rec["dst"] < 0 or rec["src1"] < 0 or rec["n_src"] != 1:
                raise CodegenError(f"unencodable VREDUCE record {rec}")
            ensure_vl(rec["vl"], rec)
            e.op(f"vfredusum.vs v{rec['dst']}, v{rec['src1']}, "
                 f"v{rec['src1']}")
        elif k == isa.VMASK_SCALAR:
            if rec["src1"] < 0 or rec["n_src"] != 1:
                raise CodegenError(f"unencodable VMASK_SCALAR record {rec}")
            ensure_vl(rec["vl"], rec)
            e.op(f"vcpop.m t6, v{rec['src1']}")
        elif k == isa.VMOVE:
            n, d, a = rec["n_src"], rec["dst"], rec["src1"]
            if d < 0:
                raise CodegenError(f"VMOVE without destination: {rec}")
            if n == 0:
                ensure_vl(rec["vl"], rec)
                e.op(f"vmv.v.i v{d}, 0")
            elif n == 1 and a >= 0:
                q, r = divmod(rec["vl"], whole)
                if r == 0 and q in (1, 2, 4, 8):
                    if d % q or a % q:
                        raise CodegenError(
                            f"vmv{q}r.v needs {q}-aligned registers: {rec}")
                    e.op(f"vmv{q}r.v v{d}, v{a}")
                else:
                    ensure_vl(rec["vl"], rec)
                    e.op(f"vmv.v.v v{d}, v{a}")
            else:
                raise CodegenError(f"unencodable VMOVE record {rec}")
        elif k == isa.NOP:
            raise CodegenError("NOP padding entries have no RVV spelling")
        else:
            raise CodegenError(f"unknown record kind {k}")


def emit(name: str, bodies: dict[int, list[dict]],
         chunks: dict[int, float], wholes: dict[int, int]) -> str:
    """Emit one kernel: ``bodies[eff]`` is the per-chunk record list at
    effective MVL ``eff``, ``chunks[eff]`` its fractional trip count, and
    ``wholes[eff]`` the whole-register move size (``cfg.mvl``) the body was
    derived at.  Returns the full ``.s`` text.
    """
    if not bodies:
        raise CodegenError("no bodies to emit")
    if set(bodies) != set(chunks) or set(bodies) != set(wholes):
        raise CodegenError("bodies/chunks/wholes must cover the same VLs")
    label = re.sub(r"\W", "_", name)
    effs = sorted(bodies)
    e = _Emitter()

    predefs = sorted(set().union(*(_predefined_regs(b)
                                   for b in bodies.values())))
    idx_regs = set().union(*(_index_regs(b) for b in bodies.values()))
    any_dep = any(rec["kind"] == isa.SCALAR_BLOCK and rec["dep_scalar"]
                  for b in bodies.values() for rec in b)

    e.label(label)
    e.op("vsetvli t0, zero, e64, m1")
    for r in predefs:
        e.op(f"vid.v v{r}" if r in idx_regs else f"vmv.v.i v{r}, 0")
    if any_dep:
        # bootstrap the hot scalar the dep_scalar filler blocks read
        if 0 not in predefs:
            e.op("vmv.v.i v0, 0")
        e.op("vcpop.m s3, v0")
    for eff in effs:
        e.op(f"li t1, {eff}")
        e.op(f"beq t0, t1, cfg_{eff}")
    e.op("j vl_bad")
    for eff in effs:
        num, den = float(chunks[eff]).as_integer_ratio()
        if num <= 0 or den <= 0:
            raise CodegenError(f"chunk count {chunks[eff]} at VL={eff} is "
                               "not positive")
        e.label(f"cfg_{eff}")
        e.op(f"li a3, {num}")
        e.op(f"li a4, {den}")
        e.op("j cfg_done")
    e.label("vl_bad")
    e.op("call abort")
    e.label("cfg_done")
    e.lines.append("    .chunk")
    e.label("loop")
    for eff in effs:
        e.op(f"li t1, {eff}")
        e.op(f"beq t0, t1, body_{eff}")
    e.op("j vl_bad")
    for eff in effs:
        e.label(f"body_{eff}")
        _emit_body(e, bodies[eff], eff, wholes[eff])
        e.op("j close")
    e.label("close")
    e.op("sub a3, a3, a4")
    e.op("bgtz a3, loop")
    e.op("ret")

    mvl_note = "/".join(str(v) for v in effs)
    head = [
        f"# {name}: RVV v1.0 kernel emitted by repro.core.codegen "
        "-- do not edit.",
        "# Decodes (repro.core.rvv) to the jaxpr-lowered trace, bitwise, at",
        f"# every effective MVL in {{{mvl_note}}}; the .chunk loop's bgtz",
        "# counter encodes the exact fractional trip count.",
        "    .text",
        f"    .globl {label}",
    ]
    head += [f"    .stream {sym} {key}" for key, sym in e.syms.items()]
    return "\n".join(head + e.lines) + "\n"


# --------------------------------------------------------------------------
# kernel-spec / app entry points
# --------------------------------------------------------------------------

def _grouped(mvls, eff_of) -> dict[int, int]:
    """Map each distinct effective MVL to the largest ``cfg.mvl`` that
    produces it (the representative configuration a body is derived at —
    the one where whole-register and VL-sized moves are distinguishable)."""
    groups: dict[int, int] = {}
    for m in mvls:
        eff = eff_of(m)
        groups[eff] = max(groups.get(eff, 0), m)
    return groups


def emit_kernel(spec, name: str, avl: int, mvls=None,
                max_vl: int | None = None) -> str:
    """Emit a frontend kernel spec (``spec(mvl, cfg) -> segments``, like
    ``App.kernel``) strip-mining ``avl`` total elements; the chunk count at
    each effective MVL is ``avl / eff``."""
    from repro.core import engine as eng
    from repro.core import frontend, rvv
    if mvls is None:
        mvls = rvv.CHECK_MVLS
    groups = _grouped(mvls, lambda m: min(m, max_vl) if max_vl else m)
    bodies, chunks, wholes = {}, {}, {}
    for eff, repr_mvl in groups.items():
        cfg = eng.VectorEngineConfig(mvl=repr_mvl, lanes=4)
        bodies[eff] = isa.trace_records(frontend.lower(spec(eff, cfg)).trace)
        chunks[eff] = avl / eff
        wholes[eff] = repr_mvl
    return emit(name, bodies, chunks, wholes)


def emit_app(app_name: str) -> str:
    """Emit ``src/repro/asm``-corpus assembly for one registered app from
    its jaxpr ``kernel=`` spec: per-VL bodies for every effective MVL the
    ``rvv.CHECK_MVLS`` grid produces, chunk counts from the app's
    characterized closed form."""
    from repro.core import engine as eng
    from repro.core import frontend, rvv, suite, tracegen
    app = tracegen.app_for(app_name)
    if app.kernel is None:
        raise CodegenError(f"{app.name} has no kernel= spec to emit from")
    groups = _grouped(
        rvv.CHECK_MVLS,
        lambda m: suite.effective_mvl(app.name,
                                      eng.VectorEngineConfig(mvl=m)))
    bodies, chunks, wholes = {}, {}, {}
    for eff, repr_mvl in groups.items():
        cfg = eng.VectorEngineConfig(mvl=repr_mvl, lanes=4)
        low = frontend.derived_body(app.name, eff, cfg)
        bodies[eff] = isa.trace_records(low.trace)
        chunks[eff] = float(app.chunks(eff))
        wholes[eff] = repr_mvl
    return emit(app.name, bodies, chunks, wholes)


# --------------------------------------------------------------------------
# CLI: the ci.sh codegen-roundtrip gate
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.codegen",
        description="Emit RVV v1.0 assembly from a registered app's jaxpr "
                    "kernel spec, or run the emit->decode round-trip gate "
                    "(--check-all).")
    ap.add_argument("app", nargs="?",
                    help="app name to emit (assembly on stdout)")
    ap.add_argument("--check-all", action="store_true",
                    help="round-trip every app with a kernel= spec at every "
                         "MVL (the ci.sh codegen-roundtrip gate)")
    args = ap.parse_args(argv)
    if args.check_all:
        from repro.core import crossval
        return 0 if crossval.print_round_trips(crossval.round_trip_all(),
                                               "codegen round trip") else 1
    if not args.app:
        ap.error("need an app name or --check-all")
    print(emit_app(args.app), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
