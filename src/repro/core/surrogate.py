"""Learned surrogate cost model: predict the exact simulator from its own cache.

The DSE engine (``repro.core.dse``) evaluates (app, config) cells *exactly*,
but exhaustive simulation tops out around the 1536-point ``SPACE_FULL`` grid.
A real design shop wants 10^6-10^8 candidates.  This module trains a small
pure-``jnp`` MLP on the simulator's own ``ResultCache`` entries so a
candidate's runtime can be *predicted* in microseconds, and the search layer
(``repro.core.search``) re-simulates only the predicted-frontier survivors —
the learned-cost-model-over-exact-profiles pattern of the XLA op-timing
literature, applied to vector-architecture parameter sweeps.

The contract, in three parts:

* **Features** (:func:`row_features`): a per-(trace, config) vector — the
  app's trace-mix features (instruction-kind/FU/memory-pattern histograms,
  element counts, footprints, chunk count, scalar residue; built on
  ``isa.Trace`` and the ``characterize`` closed forms) crossed with every
  ``VectorEngineConfig`` knob, all ``log1p``-compressed then standardized.
* **Training** (:func:`fit`): rows mined from a ``ResultCache`` by
  ``ResultCache.export_training_rows`` (a pure join — no re-simulation),
  log-runtime targets, AdamW + cosine LR from the repo's own
  ``repro.train.optimizer``, the whole step loop fused into one jitted
  ``lax.scan``.
* **Inference** (:class:`SpaceScorer`): flat design-space indices are decoded
  (mixed radix, matching ``DesignSpace.config_at``), featurized and scored
  entirely inside jit — scoring 10^6 configs is a handful of vmapped
  dispatches, no per-candidate Python.

Accuracy is never assumed: :func:`scorecard` emits the pred-vs-true
relative-error CDF, per-app worst case and Spearman rank correlation (use a
held-out app for the honest generalization number), and the search layer
re-simulates every reported frontier point exactly — surrogate predictions
never appear in final results.

>>> spearman([1.0, 2.0, 3.0, 4.0], [10.0, 20.0, 30.0, 40.0])
1.0
>>> spearman([1.0, 2.0, 3.0], [3.0, 2.0, 1.0])
-1.0
>>> len(CONFIG_FEATURES) == len(_CFG_FIELDS)
True
"""
from __future__ import annotations

from dataclasses import dataclass, fields as _dc_fields

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import isa, tracegen
from repro.train import optimizer

_CFG_FIELDS = _dc_fields(eng.VectorEngineConfig)

# --------------------------------------------------------------------------
# config features: every live VectorEngineConfig knob, numerically encoded
# --------------------------------------------------------------------------

CONFIG_FEATURES: tuple = tuple(f.name for f in _CFG_FIELDS)


def cfg_field_numeric(name: str, value) -> float:
    """Numeric encoding of one config field (bools 0/1, ``interconnect``:
    ring=1 / crossbar=0, everything else already a number)."""
    if name == "interconnect":
        return 1.0 if value == "ring" else 0.0
    if isinstance(value, bool):
        return 1.0 if value else 0.0
    return float(value)


def config_features(cfg: eng.VectorEngineConfig) -> np.ndarray:
    """The config half of a feature row: every field of the config,
    numerically encoded, in ``CONFIG_FEATURES`` order."""
    return np.asarray([cfg_field_numeric(n, getattr(cfg, n))
                       for n in CONFIG_FEATURES], np.float32)


CONFIG_FEATURE_DEFAULTS = config_features(eng.VectorEngineConfig())

# --------------------------------------------------------------------------
# trace features: the app side, a pure function of (app, cfg.mvl)
# --------------------------------------------------------------------------

TRACE_FEATURES = (
    # loop-body shape (counts per instruction kind)
    "body_len", "n_vector", "n_scalar_blocks",
    "kind_arith", "kind_load", "kind_store", "kind_slide",
    "kind_reduce", "kind_mask2s", "kind_move",
    # FU mix of the vector instructions
    "fu_simple", "fu_mul", "fu_div", "fu_trans",
    # memory access patterns
    "mem_unit", "mem_strided", "mem_indexed",
    # element-level work
    "elems_total", "elems_mem", "avg_vl_body",
    # scalar-core coupling
    "scalar_per_chunk", "dep_scalar_blocks",
    # working sets
    "footprint_max_kb", "footprint_mean_kb",
    # whole-app scale (the closed forms the runtime derivation uses)
    "chunks", "residual_scalar",
    # characterization-level mix (paper §4 definitions)
    "pct_vectorization", "avg_vl_counts", "eff_mvl",
)

# Every loop body in the registry consumes its config through cfg.mvl only
# (the clamp and canneal's full-MVL moves) — the invariant that lets the
# feature table key on (app, cfg.mvl) instead of the whole config, which is
# what makes million-point scoring a table lookup.  ``dse.cell_body`` keys
# its body memo the same way.
_TRACE_FEATS: dict[tuple, np.ndarray] = {}


def trace_features(app_name: str, mvl: int) -> np.ndarray:
    """The trace half of a feature row for one (app, configured MVL) pair."""
    key = (app_name, int(mvl))
    out = _TRACE_FEATS.get(key)
    if out is not None:
        return out
    from repro.core import suite
    cfg = eng.VectorEngineConfig(mvl=int(mvl))
    eff = suite.effective_mvl(app_name, cfg)
    body = tracegen.body_for(app_name, eff, cfg)
    chunks = tracegen.chunks_for(app_name, eff, cfg)
    counts = tracegen.app_for(app_name).counts(int(mvl))
    kinds = isa.kind_histogram(body)
    vec = body.kind != isa.SCALAR_BLOCK
    is_mem = (body.kind == isa.VLOAD) | (body.kind == isa.VSTORE)
    vls = body.vl[vec].astype(np.float64)
    n_vec = int(vec.sum())
    fu_hist = np.bincount(body.fu[vec], minlength=isa.N_FU_CLASSES)
    pat_hist = np.bincount(body.mem_pattern[is_mem], minlength=3)
    scalar_per_chunk = float(body.scalar_count.sum())
    residual = max(counts.scalar_instrs - scalar_per_chunk * chunks, 0.0)
    fp = body.footprint_kb[is_mem]
    vals = {
        "body_len": float(len(body)),
        "n_vector": float(n_vec),
        "n_scalar_blocks": float((body.kind == isa.SCALAR_BLOCK).sum()),
        "kind_arith": float(kinds[isa.VARITH]),
        "kind_load": float(kinds[isa.VLOAD]),
        "kind_store": float(kinds[isa.VSTORE]),
        "kind_slide": float(kinds[isa.VSLIDE]),
        "kind_reduce": float(kinds[isa.VREDUCE]),
        "kind_mask2s": float(kinds[isa.VMASK_SCALAR]),
        "kind_move": float(kinds[isa.VMOVE]),
        "fu_simple": float(fu_hist[isa.FU_SIMPLE]),
        "fu_mul": float(fu_hist[isa.FU_MUL]),
        "fu_div": float(fu_hist[isa.FU_DIV]),
        "fu_trans": float(fu_hist[isa.FU_TRANS]),
        "mem_unit": float(pat_hist[isa.MEM_UNIT]),
        "mem_strided": float(pat_hist[isa.MEM_STRIDED]),
        "mem_indexed": float(pat_hist[isa.MEM_INDEXED]),
        "elems_total": float(vls.sum()),
        "elems_mem": float(body.vl[is_mem].sum()),
        "avg_vl_body": float(vls.mean()) if n_vec else 0.0,
        "scalar_per_chunk": scalar_per_chunk,
        "dep_scalar_blocks": float(body.dep_scalar.sum()),
        "footprint_max_kb": float(fp.max()) if fp.size else 0.0,
        "footprint_mean_kb": float(fp.mean()) if fp.size else 0.0,
        "chunks": float(chunks),
        "residual_scalar": float(residual),
        "pct_vectorization":
            counts.vector_ops / (counts.scalar_instrs + counts.vector_ops),
        "avg_vl_counts": counts.vector_ops / max(counts.total_vector, 1),
        "eff_mvl": float(eff),
    }
    out = np.asarray([vals[n] for n in TRACE_FEATURES], np.float32)
    _TRACE_FEATS[key] = out
    return out


N_FEATURES = len(CONFIG_FEATURES) + len(TRACE_FEATURES)


def row_features(app_name: str, cfg: eng.VectorEngineConfig) -> np.ndarray:
    """One raw (un-standardized) feature row: config knobs ++ trace mix."""
    return np.concatenate([config_features(cfg),
                           trace_features(app_name, cfg.mvl)])


# --------------------------------------------------------------------------
# the model: log1p -> standardize -> 2-hidden-layer MLP -> log runtime
# --------------------------------------------------------------------------

@dataclass
class Surrogate:
    """A trained surrogate: standardization stats + MLP parameters + the
    provenance needed to trust (or distrust) it."""
    feat_mean: np.ndarray          # [F] mean of log1p features, train set
    feat_std: np.ndarray           # [F] std  of log1p features, train set
    params: dict                   # {"w1","b1","w2","b2","w3","b3"}
    apps: tuple                    # apps present in the training rows
    meta: dict                     # n_rows / steps / seed / final_loss / ...

    def predict_runtime_ns(self, rows) -> np.ndarray:
        """Predicted whole-app runtimes (ns) for export_training_rows-style
        rows — the row-at-a-time inference path (tests, scorecards).  The
        bulk path is :class:`SpaceScorer`."""
        X = np.stack([row_features(r["app"], r["cfg"]) for r in rows])
        out = np.asarray(_forward_jit(
            self.params, _standardize(X, self.feat_mean, self.feat_std)))
        return np.exp(np.clip(out, *_LOG_CLIP))


def _standardize(X, mean, std):
    return (jnp.log1p(jnp.asarray(X)) - mean) / std


# log-runtime predictions are clamped to a generous physical band before
# exponentiation (1 ns .. ~5e21 ns) so far-out-of-distribution candidates
# rank as "terrible", never as inf/nan
_LOG_CLIP = (0.0, 50.0)


def _forward(params, X):
    h = jax.nn.relu(X @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return (h @ params["w3"] + params["b3"])[:, 0]


_forward_jit = jax.jit(_forward)


def _init_params(n_in: int, hidden: int, seed: int) -> dict:
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    he = lambda k, i, o: (jax.random.normal(k, (i, o), jnp.float32)
                          * np.sqrt(2.0 / i))
    return {
        "w1": he(k1, n_in, hidden), "b1": jnp.zeros(hidden, jnp.float32),
        "w2": he(k2, hidden, hidden), "b2": jnp.zeros(hidden, jnp.float32),
        "w3": he(k3, hidden, 1), "b3": jnp.zeros(1, jnp.float32),
    }


def fit(rows, hidden: int = 64, steps: int = 1500, lr: float = 3e-3,
        seed: int = 0) -> Surrogate:
    """Train a surrogate on ``ResultCache.export_training_rows`` rows.

    Targets are ``log(runtime_ns)`` (runtimes span ~4 decades across the
    suite; the log makes the MSE a *relative*-error objective).  The whole
    optimization — AdamW with global-norm clipping and warmup+cosine LR from
    ``repro.train.optimizer`` — runs as one jitted ``lax.scan`` over
    full-batch gradient steps, so training ~15k rows takes seconds.
    Deterministic in (rows, hyperparameters, seed).
    """
    if not rows:
        raise ValueError("fit() needs at least one training row")
    X = np.stack([row_features(r["app"], r["cfg"]) for r in rows])
    y = np.log(np.asarray([r["runtime_ns"] for r in rows], np.float32))
    Xl = np.log1p(X)
    mean = Xl.mean(axis=0)
    # Features constant across the training rows (a knob the mined sweep
    # never varied) get std=1, NOT a tiny floor: they standardize to ~0 in
    # training so the model ignores them, and stay bounded when the search
    # space later sweeps them — a 1e-6 floor would turn any unseen choice
    # into a +-10^5 activation and a nonsense (inf) prediction.
    std = Xl.std(axis=0)
    std = np.where(std < 1e-6, 1.0, std)
    Xn = jnp.asarray((Xl - mean) / std)
    yj = jnp.asarray(y)

    opt_cfg = optimizer.OptConfig(
        lr=lr, b1=0.9, b2=0.95, weight_decay=1e-4, clip_norm=1.0,
        warmup_steps=min(100, steps // 10 + 1), total_steps=steps,
        min_lr_frac=0.02)
    params = _init_params(Xn.shape[1], hidden, seed)
    state = optimizer.init(params)

    def loss_fn(p):
        return jnp.mean((_forward(p, Xn) - yj) ** 2)

    def step(carry, _):
        p, s = carry
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, s, _ = optimizer.apply(opt_cfg, p, g, s)
        return (p, s), loss

    @jax.jit
    def run(params, state):
        (p, _), losses = jax.lax.scan(step, (params, state), None,
                                      length=steps)
        return p, losses

    params, losses = run(params, state)
    params = {k: np.asarray(v) for k, v in params.items()}
    return Surrogate(
        feat_mean=mean.astype(np.float32), feat_std=std.astype(np.float32),
        params={k: jnp.asarray(v) for k, v in params.items()},
        apps=tuple(sorted({r["app"] for r in rows})),
        meta={"n_rows": len(rows), "hidden": hidden, "steps": steps,
              "lr": lr, "seed": seed,
              "final_loss": float(losses[-1]),
              "model_fp": eng.model_fingerprint()})


# --------------------------------------------------------------------------
# bulk inference: score flat DesignSpace indices entirely inside jit
# --------------------------------------------------------------------------

SCORE_BATCH = 1 << 17     # fixed batch: one compiled executable per scorer


class SpaceScorer:
    """Batched surrogate inference over a ``DesignSpace`` for one app.

    ``score(idx)`` takes *flat candidate indices* and returns
    ``(predicted runtime_ns, exact area_kb)``.  Indices are decoded to axis
    digits by the same mixed-radix rule as ``DesignSpace.config_at`` (last
    axis fastest), feature columns are assembled from per-axis choice tables
    (unlisted knobs sit at their defaults), the app's trace features are a
    per-MVL-choice table lookup, and the area proxy is ``dse.area_proxy_kb``
    spelled in ``jnp`` — so no ``VectorEngineConfig`` object is ever built
    on the scoring path.  Work is dispatched in fixed ``SCORE_BATCH`` chunks
    (pad + mask), so a million-point space is ~8 dispatches of one compiled
    executable.
    """

    def __init__(self, model: Surrogate, space, app: str):
        self.model = model
        self.space = space
        self.app = app
        axes = list(space.axes)
        self._radices = [len(c) for _, c in axes]
        # per-axis numeric choice tables + their CONFIG_FEATURES column
        self._axis_cols = [CONFIG_FEATURES.index(n) for n, _ in axes]
        self._axis_vals = [
            jnp.asarray([cfg_field_numeric(n, v) for v in choices],
                        np.float32)
            for n, choices in axes]
        # the app's trace features per mvl choice (one row if mvl not swept)
        mvl_axis = [i for i, (n, _) in enumerate(axes) if n == "mvl"]
        self._mvl_axis = mvl_axis[0] if mvl_axis else None
        mvls = (axes[self._mvl_axis][1] if self._mvl_axis is not None
                else (eng.VectorEngineConfig().mvl,))
        self._trace_tab = jnp.asarray(
            np.stack([trace_features(app, m) for m in mvls]))
        self._score_jit = jax.jit(self._score_batch)

    def _score_batch(self, idx):
        """idx: [SCORE_BATCH] int32 -> (pred runtime_ns, area_kb)."""
        n_axes = len(self._radices)
        rem = idx
        digits = [None] * n_axes
        for a in range(n_axes - 1, -1, -1):     # last axis fastest
            rem, r = jnp.divmod(rem, self._radices[a])
            digits[a] = r
        # config feature matrix: defaults, overridden per swept axis
        cols = {c: jnp.full(idx.shape, CONFIG_FEATURE_DEFAULTS[c])
                for c in range(len(CONFIG_FEATURES))}
        for a in range(n_axes):
            cols[self._axis_cols[a]] = jnp.take(self._axis_vals[a],
                                                digits[a])
        cfg_mat = jnp.stack([cols[c] for c in range(len(CONFIG_FEATURES))],
                            axis=1)
        trace_mat = (self._trace_tab[digits[self._mvl_axis]]
                     if self._mvl_axis is not None
                     else jnp.broadcast_to(self._trace_tab[0],
                                           idx.shape + self._trace_tab[0].shape))
        X = jnp.concatenate([cfg_mat, trace_mat], axis=1)
        pred = jnp.exp(jnp.clip(_forward(
            self.model.params,
            _standardize(X, self.model.feat_mean, self.model.feat_std)),
            *_LOG_CLIP))
        # dse.area_proxy_kb, spelled over the feature columns
        from repro.core import dse
        g = lambda name: cols[CONFIG_FEATURES.index(name)]
        area = (g("phys_regs") * g("mvl") * 8.0 / 1024.0
                + dse.LANE_AREA_KB * g("lanes")
                + g("l1_kb") + dse.L2_SHARED_FRACTION * g("l2_kb")
                + dse.ENTRY_AREA_KB * (g("rob_entries")
                                       + 2.0 * g("queue_entries")
                                       + g("mshrs")))
        return pred, area

    def score(self, idx) -> tuple[np.ndarray, np.ndarray]:
        """Score any number of flat indices (padded to ``SCORE_BATCH``
        multiples internally); returns ``(pred_runtime_ns, area_kb)``."""
        idx = np.asarray(idx, np.int32)
        preds = np.empty(len(idx), np.float32)
        areas = np.empty(len(idx), np.float32)
        for lo in range(0, len(idx), SCORE_BATCH):
            part = idx[lo:lo + SCORE_BATCH]
            padded = np.zeros(SCORE_BATCH, np.int32)
            padded[:len(part)] = part
            p, a = self._score_jit(jnp.asarray(padded))
            preds[lo:lo + SCORE_BATCH] = np.asarray(p)[:len(part)]
            areas[lo:lo + SCORE_BATCH] = np.asarray(a)[:len(part)]
        return preds, areas


# --------------------------------------------------------------------------
# the accuracy scorecard: every speed claim carries a trust number
# --------------------------------------------------------------------------

def _ranks(x) -> np.ndarray:
    """Average ranks (ties share their mean rank), scipy-free."""
    x = np.asarray(x, np.float64)
    order = np.argsort(x, kind="mergesort")
    r = np.empty(len(x), np.float64)
    r[order] = np.arange(len(x), dtype=np.float64)
    _, inv, cnt = np.unique(x, return_inverse=True, return_counts=True)
    sums = np.zeros(len(cnt))
    np.add.at(sums, inv, r)
    return sums[inv] / cnt[inv]


def spearman(a, b) -> float:
    """Spearman rank correlation (average-rank tie handling)."""
    ra, rb = _ranks(a), _ranks(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    return float((ra * rb).sum() / denom) if denom else 0.0


def scorecard(model: Surrogate, rows, holdout_app: str | None = None) -> dict:
    """Pred-vs-true accuracy report over labeled rows.

    Emits the relative-error CDF percentiles (p50/p90/p99/max on the natural
    runtime scale), per-app mean/worst error and Spearman rank correlation.
    When ``holdout_app`` names an app in ``rows``, its block is additionally
    surfaced as ``holdout`` — train the model *without* that app and this is
    the honest unseen-workload generalization number.
    """
    pred = model.predict_runtime_ns(rows)
    true = np.asarray([r["runtime_ns"] for r in rows], np.float64)
    rel = np.abs(pred - true) / true
    apps = sorted({r["app"] for r in rows})
    per_app = {}
    for app in apps:
        m = np.asarray([r["app"] == app for r in rows])
        per_app[app] = {
            "n": int(m.sum()),
            "mean_rel_err": float(rel[m].mean()),
            "worst_rel_err": float(rel[m].max()),
            "spearman": spearman(pred[m], true[m]),
            "trained_on": app in model.apps,
        }
    card = {
        "n_rows": len(rows),
        "rel_err_p50": float(np.percentile(rel, 50)),
        "rel_err_p90": float(np.percentile(rel, 90)),
        "rel_err_p99": float(np.percentile(rel, 99)),
        "rel_err_max": float(rel.max()),
        "spearman_all": spearman(pred, true),
        "per_app": per_app,
    }
    if holdout_app is not None and holdout_app in per_app:
        card["holdout"] = dict(per_app[holdout_app], app=holdout_app)
    return card
