"""Frontend-only ML workloads: flash attention, decode attention, SSD scan.

The three extra Pallas kernels in ``src/repro/kernels/`` (`flash_attention`,
`decode_attention`, `ssd_scan`) could not be simulated before: they had
numerics but no trace.  Here each gets a *chunk kernel spec* — the RVV-style
vectorization of one MVL-chunk of the kernel's inner loop, written as plain
JAX and lowered by ``repro.core.frontend`` — so they participate in the full
24-config batched sweep, the golden regression, and the module-stress
classification exactly like the seven RiVec apps.

Vectorization choices (the "how would this run on the paper's machine"
mapping, mirroring the Pallas kernels' math):

* **flash_attention** — one chunk = one query row against MVL keys (K/V
  pre-transposed so key-dim accesses are unit-stride).  Per chunk: the q·K
  dot chain, an online-softmax max/sum pair of reductions whose results the
  scalar core consumes (`dep_scalar`, the §4.1.4 round trip), and the p·V
  accumulation as per-dim multiply+reduce.  Reduction-heavy → stresses the
  lane interconnect; the per-head K/V block (512 KB) is the Fig-10-style
  LLC lever.
* **decode_attention** — one chunk = one (batch, head) against MVL cached
  keys, with the valid-length mask (iota-compare-select).  The KV cache is
  streamed with no reuse (multi-MB footprint) and V is strided → DRAM
  bandwidth bound, the memory-wall workload of the three.
* **ssd_scan** — one chunk = MVL timesteps of the Mamba-2 chunk scan: the
  `cumsum` decay prefix lowers to the RVV slide+add ladder
  (`ceil(log2(vl))` rounds), plus exp-heavy state weighting and a rank-1
  state reduction → slide/transcendental-heavy.

Counts models are *derived from the lowered trace* (per-chunk instruction
and element counts x a closed-form chunk count), with a scalar-version
overhead factor standing in for the paper's scalar-code measurements; these
workloads have no published tables, so ``docs/calibration.md`` marks them
modeled-not-paper-calibrated.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import frontend as fe
from repro.core import isa

# ---------------------------------------------------------------- workload scales
_FA_B, _FA_H, _FA_S, _FA_D = 4, 8, 2048, 64
_FA_KV_KB = _FA_S * _FA_D * 4 / 1024          # one head's K (=V) block: 512 KB

_DA_B, _DA_H, _DA_S, _DA_D = 32, 8, 4096, 64
_DA_KV_KB = _DA_S * _DA_H * _DA_D * 4 / 1024  # streamed cache slice: 8 MB

_SSD_B, _SSD_S, _SSD_H = 8, 65536, 16
_SSD_SEQ_KB = _SSD_S * 4 / 1024               # one (b,h) sequence array: 256 KB

# scalar-version overhead factor: loop/addressing instructions per element
# op in the scalar code (the closed forms' s1-equivalent, modeled)
_FA_OVH, _DA_OVH, _SSD_OVH = 0.4, 0.4, 0.5


def _attention_spec(vl, D, kv_kb, v_pattern=isa.MEM_UNIT, masked=False):
    """Shared chunk spec of both attention kernels: q·K dot chain, online
    softmax with the vfred→scalar round trip, p·V per-dim accumulation.
    ``masked`` adds decode's valid-length (iota-compare-select) mask."""
    k_streams = tuple(fe.Stream(f"k{d}", kv_kb) for d in range(D))
    v_streams = tuple(fe.Stream(f"v{d}", kv_kb, pattern=v_pattern)
                      for d in range(D))

    def score(*kcols):
        s = kcols[0] * 0.125
        for d in range(1, D):
            s = s + kcols[d] * 0.125
        if masked:
            ki = jnp.arange(vl)         # iota: immediate
            s = jnp.where(ki < vl - 1, s, -1e30)
        m = jnp.max(s)                  # online-softmax running max
        p = jnp.exp(s - m)
        l = jnp.sum(p)                  # noqa: F841  (scalar core consumes it)
        return p

    def accum(p, *vcols):
        t = p
        for d in range(D):
            t = p * vcols[d]
            o_d = jnp.sum(t)            # noqa: F841  per-dim output element
        return t

    return [
        fe.KernelBody(score, vl, ins=k_streams, outs=("p",), lazy_loads=True),
        # m/l running-statistics update on the scalar core, fed by the
        # reductions above (vfred -> scalar round trip)
        fe.ScalarWork(6, dep_scalar=True),
        fe.KernelBody(accum, vl, ins=("p",) + v_streams,
                      outs=(fe.Stream("o", kv_kb),), lazy_loads=True),
    ]


def _fa_kernel(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    return _attention_spec(vl, _FA_D, _FA_KV_KB)


def _da_kernel(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    return _attention_spec(vl, _DA_D, _DA_KV_KB,
                           v_pattern=isa.MEM_STRIDED, masked=True)


def _ssd_kernel(mvl, cfg):
    vl = min(mvl, cfg.mvl) if cfg else mvl
    ins = (fe.Stream("x", _SSD_SEQ_KB), fe.Stream("dt", _SSD_SEQ_KB),
           fe.Stream("B", _SSD_SEQ_KB), fe.Stream("C", _SSD_SEQ_KB))

    def fn(x, dt, b, c):
        dA = dt * -0.05
        seg = jnp.cumsum(dA)            # decay prefix: slide+add ladder
        g = jnp.exp(seg)
        gi = jnp.exp(-seg)
        xd = x * dt
        w = b * xd
        w = w * gi
        snew = jnp.sum(w)               # rank-1 state update
        y = c * g
        y = y * snew
        return y + xd * 0.5             # D-skip path

    return [fe.KernelBody(fn, vl, ins=ins,
                          outs=(fe.Stream("y", _SSD_SEQ_KB),))]


_SPECS = {
    "flash_attention": (_fa_kernel,
                        lambda mvl: _FA_B * _FA_H * _FA_S * (_FA_S / 2) / mvl,
                        _FA_OVH),
    "decode_attention": (_da_kernel,
                         lambda mvl: _DA_B * _DA_H * _DA_S / mvl,
                         _DA_OVH),
    "ssd_scan": (_ssd_kernel,
                 lambda mvl: _SSD_B * _SSD_H * _SSD_S / mvl,
                 _SSD_OVH),
}

NOTES = {
    "flash_attention": "reduction/scalar-comm heavy; LLC-sensitive KV block",
    "decode_attention": "DRAM-bandwidth bound; strided V; streamed KV cache",
    "ssd_scan": "cumsum slide ladder + transcendental decay; Mamba-2 SSD",
}

_TRACE_CACHE: dict = {}


def _chunk_trace(name: str, mvl: int) -> isa.Trace:
    key = (name, mvl)
    out = _TRACE_CACHE.get(key)
    if out is None:
        out = _TRACE_CACHE[key] = fe.lower_trace(_SPECS[name][0](mvl, None))
    return out


class _LazyMix(dict):
    """App.mix derived from the lowered chunk trace, materialized on first
    access — keeps `import repro.core.tracegen` free of jax tracing."""

    def __init__(self, name):
        super().__init__()
        self._name = name
        self._filled = False

    def _fill(self):
        if not self._filled:
            self._filled = True
            self.update(fe.trace_mix(_chunk_trace(self._name, 64)))

    def __getitem__(self, k):
        self._fill()
        return super().__getitem__(k)

    def get(self, k, default=None):
        self._fill()
        return super().get(k, default)

    def items(self):
        self._fill()
        return super().items()

    def values(self):
        self._fill()
        return super().values()

    def keys(self):
        self._fill()
        return super().keys()


def make_apps(App, Counts) -> dict:
    """Build the three App entries (App/Counts passed in by tracegen to keep
    the import acyclic).  Counts are derived from the lowered chunk trace:
    per-chunk instruction/element totals x the closed-form chunk count."""
    apps = {}
    for name, (kernel, chunks_fn, ovh) in _SPECS.items():
        def counts_fn(mvl, name=name, chunks_fn=chunks_fn, ovh=ovh):
            tr = _chunk_trace(name, mvl)
            ch = chunks_fn(mvl)
            k = tr.kind
            vec = (k != isa.SCALAR_BLOCK) & (k != isa.NOP)
            mem = float(np.sum((k == isa.VLOAD) | (k == isa.VSTORE)))
            arith = float(np.sum((k == isa.VARITH) | (k == isa.VMOVE)))
            manip = float(np.sum(np.isin(
                k, (isa.VSLIDE, isa.VREDUCE, isa.VMASK_SCALAR))))
            ops = float(tr.vl[vec].sum()) * ch
            scalar = float(tr.scalar_count.sum()) * ch + 1e6
            return Counts(
                scalar_code_total=ops * (1.0 + ovh) + scalar,
                scalar_instrs=scalar,
                vector_mem=mem * ch, vector_arith=arith * ch,
                vector_manip=manip * ch, vector_ops=ops)

        apps[name] = App(
            name,
            counts_fn,
            lambda mvl, cfg, kernel=kernel: fe.lower_trace(kernel(mvl, cfg)),
            chunks_fn,
            _LazyMix(name),
            kernel=kernel,
            asm=f"{name}.s",
            notes=NOTES[name])
    return apps
