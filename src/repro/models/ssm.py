"""Mamba-2 SSD (state-space duality) block — chunked scan formulation.

Training/prefill uses the SSD chunked algorithm (arXiv:2405.21060 §6): quadratic
attention-like computation *within* a chunk, linear state recurrence *across*
chunks via ``lax.scan`` — so a 524288-token context never materializes anything
quadratic in S.  Decode is the O(1) recurrent update.  The chunk length is the
TPU analogue of the paper's MVL (a tunable vector length); the Pallas
``ssd_scan`` kernel is the hillclimbed version of the same computation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models.layers import PD

CONV_K = 4  # depthwise causal conv width


def ssd_defs(cfg):
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = DI + 2 * N
    return {
        "wz": PD((D, DI), ("embed", "ssm_inner")),
        "wx": PD((D, DI), ("embed", "ssm_inner")),
        "wB": PD((D, N), ("embed", None)),
        "wC": PD((D, N), ("embed", None)),
        "wdt": PD((D, H), ("embed", "ssm_heads")),
        "dt_bias": PD((H,), ("ssm_heads",), "zeros"),
        "A_log": PD((H,), ("ssm_heads",), "ones"),
        "D_skip": PD((H,), ("ssm_heads",), "ones"),
        "conv_w": PD((conv_dim, CONV_K), ("ssm_inner", None), scale=0.5),
        "conv_b": PD((conv_dim,), ("ssm_inner",), "zeros"),
        "gate_norm": PD((DI,), ("ssm_inner",), "ones"),
        "wo": PD((DI, D), ("ssm_inner", "embed")),
    }


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, xBC [B,S,C], w [C,K]."""
    B, S, C = xBC.shape
    pad = jnp.pad(xBC, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for k in range(CONV_K):
        out = out + pad[:, k:k + S, :].astype(jnp.float32) * w[:, k]
    return jax.nn.silu(out + b).astype(xBC.dtype)


def _ssd_chunked(x, dt, A, Bm, Cm, D_skip, chunk):
    """SSD core.  x [B,S,H,P]; dt [B,S,H]; A [H]; Bm/Cm [B,S,N].

    Returns y [B,S,H,P] and final state [B,H,P,N].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    f32 = jnp.float32

    dA = dt.astype(f32) * A.astype(f32)                      # [B,S,H] (negative)
    xd = x.astype(f32) * dt.astype(f32)[..., None]           # dt-weighted input
    # chunked views, scan axis leading
    rs = lambda t, d: t.reshape((Bsz, nc, Q) + t.shape[2:]).swapaxes(0, 1)
    dAc, xc = rs(dA, 3), rs(xd, 4)
    Bc, Cc = rs(Bm.astype(f32), 3), rs(Cm.astype(f32), 3)

    tri = jnp.tril(jnp.ones((Q, Q), f32))
    idx = jnp.arange(Q)

    def body(state, ch):
        dAq, xq, Bq, Cq = ch                                  # [B,Q,H], [B,Q,H,P], [B,Q,N]
        seg = jnp.cumsum(dAq, axis=1)                         # [B,Q,H]
        # intra-chunk: scores[t,u] = (C_t.B_u) * exp(seg_t - seg_u) for u<=t
        diff = seg[:, :, None] - seg[:, None, :, :]           # [B,Q,Q,H]
        diff = jnp.where(tri[None, :, :, None] > 0, diff, -jnp.inf)  # mask pre-exp
        decay = jnp.exp(diff)
        cb = jnp.einsum("btn,bun->btu", Cq, Bq)               # [B,Q,Q]
        y_intra = jnp.einsum("btu,btuh,buhp->bthp", cb, decay, xq)
        # contribution of carried-in state: y_state[t] = exp(seg_t) * C_t . state
        y_state = jnp.einsum("btn,bhpn,bth->bthp", Cq, state, jnp.exp(seg))
        # chunk end state: state' = exp(seg_Q) * state + sum_u exp(seg_Q-seg_u) B_u x_u
        tot = seg[:, -1]                                      # [B,H]
        sdecay = jnp.exp(tot[:, None] - seg)                  # [B,Q,H]
        state_new = (jnp.exp(tot)[:, :, None, None] * state
                     + jnp.einsum("bun,buhp,buh->bhpn", Bq, xq, sdecay))
        return state_new, y_intra + y_state

    state0 = jnp.zeros((Bsz, H, P, N), f32)
    state, yc = jax.lax.scan(body, state0, (dAc, xc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(Bsz, S, H, P)
    y = y + x.astype(f32) * D_skip.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_block_fwd(p, h, cfg, state=None, return_state=False):
    """Full-sequence SSD block. h [B,S,D] -> [B,S,D]."""
    B, S, D = h.shape
    DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    P = cfg.ssm_headdim
    z = h @ p["wz"]
    xBC = jnp.concatenate([h @ p["wx"], h @ p["wB"], h @ p["wC"]], axis=-1)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    x, Bm, Cm = jnp.split(xBC, [DI, DI + N], axis=-1)
    dt = jax.nn.softplus((h @ p["wdt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    x = constraint(x.reshape(B, S, H, P), ("batch", None, "ssm_heads", None))
    y, final_state = _ssd_chunked(x, dt, A, Bm, Cm, p["D_skip"], cfg.ssm_chunk)
    y = y.reshape(B, S, DI)
    y = L.rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["wo"]
    if return_state:
        return out, final_state
    return out


def ssd_decode_step(p, h, cfg, conv_state, ssm_state):
    """Single-token recurrent update.

    h [B,1,D]; conv_state [B,K-1,conv_dim]; ssm_state [B,H,P,N] (fp32).
    """
    B = h.shape[0]
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    z = h @ p["wz"]
    xBC_new = jnp.concatenate([h @ p["wx"], h @ p["wB"], h @ p["wC"]], axis=-1)  # [B,1,C]
    window = jnp.concatenate([conv_state, xBC_new], axis=1)        # [B,K,C]
    conv_out = (window.astype(jnp.float32) * p["conv_w"].T[None]).sum(1) + p["conv_b"]
    xBC = jax.nn.silu(conv_out).astype(h.dtype)                    # [B,C]
    x, Bm, Cm = jnp.split(xBC, [DI, DI + N], axis=-1)
    dt = jax.nn.softplus((h[:, 0] @ p["wdt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    x = x.reshape(B, H, P).astype(jnp.float32)
    dA = jnp.exp(dt * A)                                           # [B,H]
    dBx = jnp.einsum("bn,bhp,bh->bhpn", Bm.astype(jnp.float32), x, dt)
    ssm_state = ssm_state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, Cm.astype(jnp.float32))
    y = y + x * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, DI).astype(h.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["wo"], window[:, 1:], ssm_state


# ---------------------------------------------------------------------------
# Mamba-2 LM (mamba2-130m)
# ---------------------------------------------------------------------------

def block_defs(cfg):
    return {"norm": PD((cfg.d_model,), ("embed",), "ones"), "ssd": ssd_defs(cfg)}


def model_defs(cfg):
    from repro.models.transformer import stacked
    return {
        "embed": L.embed_defs(cfg),
        "blocks": stacked(block_defs(cfg), cfg.num_layers),
        "final_norm": PD((cfg.d_model,), ("embed",), "ones"),
    }


def forward(params, tokens, cfg):
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)

    def body(h, bp):
        bp = L.fsdp_gather(bp, block_defs(cfg))
        return h + ssd_block_fwd(bp["ssd"], L.rmsnorm(h, bp["norm"], cfg.norm_eps), cfg), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    return L.rmsnorm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg):
    h = forward(params, batch["tokens"], cfg)
    logits = L.unembed_fwd(params["embed"], h)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg, batch, max_seq, dtype):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((cfg.num_layers, batch, CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((cfg.num_layers, batch, cfg.ssm_nheads,
                          cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
    }


def cache_logical(cfg):
    return {
        "conv": ("layers", "batch", None, "ssm_inner"),
        "ssm": ("layers", "batch", "ssm_heads", None, None),
    }


def decode_step(params, cache, tokens, pos, cfg):
    del pos  # SSM state is position-free
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)

    def body(carry, bp):
        h, conv_all, ssm_all, i = carry
        bp = L.fsdp_gather(bp, block_defs(cfg))
        conv = jax.lax.dynamic_index_in_dim(conv_all, i, 0, keepdims=False)
        ssm = jax.lax.dynamic_index_in_dim(ssm_all, i, 0, keepdims=False)
        y, conv, ssm = ssd_decode_step(
            bp["ssd"], L.rmsnorm(h, bp["norm"], cfg.norm_eps), cfg, conv, ssm)
        conv_all = jax.lax.dynamic_update_slice_in_dim(conv_all, conv[None], i, 0)
        ssm_all = jax.lax.dynamic_update_slice_in_dim(ssm_all, ssm[None], i, 0)
        return (h + y, conv_all, ssm_all, i + 1), None

    (h, conv_all, ssm_all, _), _ = jax.lax.scan(
        body, (h, cache["conv"], cache["ssm"], jnp.int32(0)), params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.unembed_fwd(params["embed"], h), {"conv": conv_all, "ssm": ssm_all}


def prefill(params, tokens, cfg, max_seq):
    """Run prompt through SSD blocks, returning final recurrent states."""
    del max_seq  # state is O(1); no KV growth
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)

    def body(h, bp):
        bp = L.fsdp_gather(bp, block_defs(cfg))
        y, state = ssd_block_fwd(
            bp["ssd"], L.rmsnorm(h, bp["norm"], cfg.norm_eps), cfg, return_state=True)
        return h + y, state

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, states = jax.lax.scan(body, h, params["blocks"])
    hn = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], hn[:, -1:])
    # conv state: last K-1 xBC inputs are not tracked through scan here; a
    # serving deployment re-computes them from the prompt tail (3 tokens).
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    cache = {
        "conv": jnp.zeros((cfg.num_layers, tokens.shape[0], CONV_K - 1, conv_dim),
                          cfg.jnp_dtype),
        "ssm": states.astype(jnp.float32),
    }
    return logits, cache
