from repro.models.api import Model, batch_logical, build, input_specs
