"""Unified model API: build(cfg) -> Model with init / loss / prefill / decode.

Every assigned architecture is reachable through this one interface; the
launcher, dry-run, trainer and server never special-case a family beyond the
input signature differences captured by ``input_specs``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import layers as L
from repro.models import encdec, hybrid, moe, ssm, transformer, vlm

_FAMILY = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
}


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    mod: Any

    # ---- parameters -------------------------------------------------------
    def defs(self):
        return self.mod.model_defs(self.cfg)

    def init(self, key):
        return L.init_params(self.defs(), key, self.cfg.jnp_dtype)

    def param_structs(self):
        return L.param_structs(self.defs(), self.cfg.jnp_dtype)

    def param_logical(self):
        return L.param_logical(self.defs())

    # ---- training ---------------------------------------------------------
    def loss(self, params, batch):
        return self.mod.loss_fn(params, batch, self.cfg)

    # ---- serving ----------------------------------------------------------
    def prefill(self, params, batch, max_seq):
        cfg = self.cfg
        if cfg.family == "encdec":
            return self.mod.prefill(params, batch["frames"], batch["tokens"], cfg, max_seq)
        if cfg.family == "vlm":
            return self.mod.prefill(params, batch["patches"], batch["tokens"], cfg, max_seq)
        return self.mod.prefill(params, batch["tokens"], cfg, max_seq)

    def decode_step(self, params, cache, tokens, pos):
        return self.mod.decode_step(params, cache, tokens, pos, self.cfg)

    def init_cache(self, batch, max_seq):
        return self.mod.init_cache(self.cfg, batch, max_seq, self.cfg.jnp_dtype)

    def cache_logical(self):
        return self.mod.cache_logical(self.cfg)

    def cache_structs(self, batch, max_seq):
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq))


def build(cfg: ModelConfig) -> Model:
    return Model(cfg, _FAMILY[cfg.family])


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; never allocate)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Stand-ins for every model input of the given shape cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct((B, s), jnp.int32)
    dt = cfg.jnp_dtype
    if shape.kind == "train":
        batch = {"tokens": tok(S), "labels": tok(S)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((B, cfg.num_frames, cfg.d_model), dt)
        if cfg.family == "vlm":
            P = cfg.num_patches
            batch = {"tokens": tok(S - P), "labels": tok(S - P),
                     "patches": jax.ShapeDtypeStruct((B, P, cfg.d_model), dt)}
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": tok(S)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((B, cfg.num_frames, cfg.d_model), dt)
        if cfg.family == "vlm":
            P = cfg.num_patches
            batch = {"tokens": tok(S - P),
                     "patches": jax.ShapeDtypeStruct((B, P, cfg.d_model), dt)}
        return batch
    if shape.kind == "decode":
        return {"tokens": tok(1)}
    raise ValueError(shape.kind)


def batch_logical(cfg: ModelConfig, shape: InputShape) -> dict:
    """Logical axes for the input batch (data-parallel over batch dim)."""
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if k in ("tokens", "labels"):
            out[k] = ("batch", None)
        elif k in ("frames", "patches"):
            out[k] = ("batch", None, None)
        else:
            out[k] = tuple([None] * len(v.shape))
    return out
