"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is STUBBED per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, num_frames, d_model].  Positions use
sinusoidal embeddings (adaptation: whisper uses sinusoidal-encoder /
learned-decoder; we use sinusoidal for both so parameters are independent of
the input-shape cell).  Decoder blocks: causal self-attention (KV cache at
serve time) + cross-attention over encoder output + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models.layers import PD
from repro.models.transformer import stacked


def sinusoid(positions, d_model, dtype):
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def enc_block_defs(cfg):
    return {
        "attn_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "attn": L.attention_defs(cfg),
        "mlp_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "mlp": L.mlp_defs(cfg),
    }


def dec_block_defs(cfg):
    return {
        "self_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "self_attn": L.attention_defs(cfg),
        "cross_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "cross_attn": L.attention_defs(cfg),
        "mlp_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "mlp": L.mlp_defs(cfg),
    }


def model_defs(cfg):
    return {
        "embed": L.embed_defs(cfg),
        "enc_blocks": stacked(enc_block_defs(cfg), cfg.encoder_layers),
        "enc_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "dec_blocks": stacked(dec_block_defs(cfg), cfg.num_layers),
        "final_norm": PD((cfg.d_model,), ("embed",), "ones"),
    }


def encode(params, frames, cfg):
    """frames [B,F,D] (stub embeddings) -> encoder hidden [B,F,D]."""
    dtype = cfg.jnp_dtype
    B, F, _ = frames.shape
    h = frames.astype(dtype) + sinusoid(jnp.arange(F)[None], cfg.d_model, dtype)
    positions = jnp.arange(F)[None, :]

    def body(h, bp):
        bp = L.fsdp_gather(bp, enc_block_defs(cfg))
        a, _ = L.attention_fwd(bp["attn"], L.rmsnorm(h, bp["attn_norm"], cfg.norm_eps),
                               cfg, positions=positions, causal=False)
        h = h + a
        h = h + L.mlp_fwd(bp["mlp"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps))
        return constraint(h, ("batch", "seq_sp", None)), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return L.rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def _dec_block(bp, h, enc_kv, cfg, positions):
    a, _ = L.attention_fwd(bp["self_attn"], L.rmsnorm(h, bp["self_norm"], cfg.norm_eps),
                           cfg, positions=positions, causal=True)
    h = h + a
    c, _ = L.attention_fwd(bp["cross_attn"], L.rmsnorm(h, bp["cross_norm"], cfg.norm_eps),
                           cfg, positions=positions, kv=enc_kv)
    h = h + c
    h = h + L.mlp_fwd(bp["mlp"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps))
    return constraint(h, ("batch", "seq_sp", None))


def _cross_kv(bp, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output."""
    B, F, _ = enc_out.shape
    k = (enc_out @ bp["cross_attn"]["wk"])
    v = (enc_out @ bp["cross_attn"]["wv"])
    if "bk" in bp["cross_attn"]:
        k, v = k + bp["cross_attn"]["bk"], v + bp["cross_attn"]["bv"]
    k = k.reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def forward(params, frames, tokens, cfg):
    enc_out = encode(params, frames, cfg)
    dtype = cfg.jnp_dtype
    B, Sq = tokens.shape
    h = L.embed_fwd(params["embed"], tokens, dtype)
    h = h + sinusoid(jnp.arange(Sq)[None], cfg.d_model, dtype)
    positions = jnp.arange(Sq)[None, :]

    def body(h, bp):
        bp = L.fsdp_gather(bp, dec_block_defs(cfg))
        kv = _cross_kv(bp, enc_out, cfg)
        return _dec_block(bp, h, kv, cfg, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    return L.rmsnorm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg):
    h = forward(params, batch["frames"], batch["tokens"], cfg)
    logits = L.unembed_fwd(params["embed"], h)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg, batch, max_seq, dtype):
    F = cfg.num_frames
    cdt = jnp.dtype(cfg.cache_dtype)
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cdt),
        "v": jnp.zeros((cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cdt),
        "xk": jnp.zeros((cfg.num_layers, batch, F, cfg.num_kv_heads, cfg.head_dim), dtype),
        "xv": jnp.zeros((cfg.num_layers, batch, F, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def cache_logical(cfg):
    kv = ("layers", "batch", "seq_kv", "kv_heads", None)
    xkv = ("layers", "batch", None, "kv_heads", None)
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}


def prefill(params, frames, tokens, cfg, max_seq):
    """Encode audio + run prompt tokens; returns (logits, cache incl. cross-KV)."""
    enc_out = encode(params, frames, cfg)
    dtype = cfg.jnp_dtype
    B, Sq = tokens.shape
    h = L.embed_fwd(params["embed"], tokens, dtype)
    h = h + sinusoid(jnp.arange(Sq)[None], cfg.d_model, dtype)
    positions = jnp.arange(Sq)[None, :]

    def body(h, bp):
        bp = L.fsdp_gather(bp, dec_block_defs(cfg))
        xk, xv = _cross_kv(bp, enc_out, cfg)
        a, (k, v) = L.attention_fwd(
            bp["self_attn"], L.rmsnorm(h, bp["self_norm"], cfg.norm_eps), cfg,
            positions=positions, causal=True)
        h = h + a
        c, _ = L.attention_fwd(bp["cross_attn"], L.rmsnorm(h, bp["cross_norm"], cfg.norm_eps),
                               cfg, positions=positions, kv=(xk, xv))
        h = h + c
        h = h + L.mlp_fwd(bp["mlp"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps))
        return h, (k, v, xk, xv)

    h, (k_all, v_all, xk_all, xv_all) = jax.lax.scan(body, h, params["dec_blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], h[:, -1:])
    pad = max_seq - Sq
    cache = {
        "k": jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "xk": xk_all, "xv": xv_all,
    }
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg):
    dtype = cfg.jnp_dtype
    h = L.embed_fwd(params["embed"], tokens, dtype)
    h = h + sinusoid(jnp.full((1, 1), pos, jnp.int32), cfg.d_model, dtype)

    def body(h, layer):
        bp, ck, cv, xk, xv = layer
        bp = L.fsdp_gather(bp, dec_block_defs(cfg))
        a, ck, cv = L.attention_decode(
            bp["self_attn"], L.rmsnorm(h, bp["self_norm"], cfg.norm_eps), cfg, ck, cv, pos)
        h = h + a
        # cross attention against fixed encoder K/V
        hn = L.rmsnorm(h, bp["cross_norm"], cfg.norm_eps)
        q = (hn @ bp["cross_attn"]["wq"])
        if "bq" in bp["cross_attn"]:
            q = q + bp["cross_attn"]["bq"]
        B = h.shape[0]
        q = q.reshape(B, 1, cfg.num_heads, cfg.head_dim)
        kk, vv = L._repeat_kv(xk.astype(dtype), xv.astype(dtype), cfg)
        c = L._exact_attn(q, kk, vv, causal=False)
        c = c.reshape(B, 1, cfg.num_heads * cfg.head_dim) @ bp["cross_attn"]["wo"]
        h = h + c
        h = h + L.mlp_fwd(bp["mlp"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps))
        return h, (ck, cv)

    def scan_body(carry, xs):
        h, ck_all, cv_all, i = carry
        bp, xk, xv = xs
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        h, (ck, cv) = body(h, (bp, ck, cv, xk, xv))
        ck_all = jax.lax.dynamic_update_slice_in_dim(ck_all, ck[None], i, 0)
        cv_all = jax.lax.dynamic_update_slice_in_dim(cv_all, cv[None], i, 0)
        return (h, ck_all, cv_all, i + 1), None

    (h, ck_all, cv_all, _), _ = jax.lax.scan(
        scan_body, (h, cache["k"], cache["v"], jnp.int32(0)),
        (params["dec_blocks"], cache["xk"], cache["xv"]))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], h)
    return logits, {"k": ck_all, "v": cv_all, "xk": cache["xk"], "xv": cache["xv"]}
