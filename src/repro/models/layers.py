"""Shared model components: param-def framework, norms, RoPE, attention, MLP.

Parameters are declared as ``PD(shape, logical, init)`` leaves in nested dicts.
``init_params`` materializes them, ``param_structs`` gives ShapeDtypeStructs for
the dry-run, ``param_logical`` gives the logical-axis tree the sharding rules
consume.  Attention is chunked/online-softmax for long sequences so the
*baseline* memory term stays within HBM (the Pallas flash kernel is the
hillclimbed version).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constraint


class PD(NamedTuple):
    shape: tuple
    logical: tuple
    init: str = "normal"     # normal | zeros | ones
    scale: Optional[float] = None  # stddev override (default: fan-in)


def _is_pd(x):
    return isinstance(x, PD)


def init_params(defs, key, dtype):
    flat, treedef = jax.tree.flatten(defs, is_leaf=_is_pd)
    keys = jax.random.split(key, len(flat))
    out = []
    for k, pd in zip(keys, flat):
        if pd.init == "zeros":
            out.append(jnp.zeros(pd.shape, dtype))
        elif pd.init == "ones":
            out.append(jnp.ones(pd.shape, dtype))
        else:
            fan_in = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
            scale = pd.scale if pd.scale is not None else fan_in ** -0.5
            out.append((jax.random.normal(k, pd.shape) * scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def param_structs(defs, dtype):
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, dtype), defs, is_leaf=_is_pd)


def param_logical(defs):
    return jax.tree.map(lambda pd: pd.logical, defs, is_leaf=_is_pd)


# ---------------------------------------------------------------------------
# Norms / RoPE
# ---------------------------------------------------------------------------

def fsdp_gather(block_params, block_defs):
    """Undo FSDP (data-axis) sharding on a block's params *inside* the scan body.

    Without this, GSPMD hoists the weight all-gathers out of the microbatch
    loop and materializes every layer's gathered weights at once (26 GiB for
    mistral-123b).  Constraining the per-layer slice keeps the gather inside
    the loop: one layer's weights live at a time.  TP sharding is preserved —
    only the "embed" (fsdp) axis is dropped.
    """
    logical = param_logical(block_defs)
    return jax.tree.map(
        lambda x, lg: constraint(x, lg, rules={"embed": None}), block_params, logical)


def rmsnorm(x, w, eps):
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(x.dtype) * w


def rope_tables(positions, head_dim, theta, dtype):
    """positions: int32 [...]; returns cos/sin [..., head_dim//2]."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: [..., S, H, D]; cos/sin: [..., S, D//2] broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

ATTN_CHUNK = 1024          # online-softmax KV/Q chunk for long sequences
EXACT_ATTN_MAX_SEQ = 2048  # below this, materialize scores exactly


def attention_defs(cfg, d_model=None):
    d = d_model or cfg.d_model
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": PD((d, H * hd), ("embed", "heads")),
        "wk": PD((d, KV * hd), ("embed", "kv_heads")),
        "wv": PD((d, KV * hd), ("embed", "kv_heads")),
        "wo": PD((H * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = PD((H * hd,), ("heads",), "zeros")
        defs["bk"] = PD((KV * hd,), ("kv_heads",), "zeros")
        defs["bv"] = PD((KV * hd,), ("kv_heads",), "zeros")
    return defs


def _project_qkv(p, h, cfg):
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = h.shape[:2]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    return q, k, v


def _repeat_kv(k, v, cfg):
    g = cfg.num_heads // cfg.num_kv_heads
    if g > 1:
        k = jnp.repeat(k, g, axis=-2)
        v = jnp.repeat(v, g, axis=-2)
    return k, v


def _exact_attn(q, k, v, causal, q_offset=0, kv_len=None):
    """q [B,Sq,H,D], k/v [B,Sk,H,D]."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    Sq, Sk = q.shape[1], k.shape[1]
    if kv_len is not None:  # decode against a cache filled up to kv_len
        mask = jnp.arange(Sk)[None, :] < (kv_len[:, None] if kv_len.ndim else kv_len)
        s = jnp.where(mask[:, None, None, :] if kv_len.ndim else mask[None, None],
                      s, -1e30)
    if causal:
        qi = jnp.arange(Sq) + q_offset
        ki = jnp.arange(Sk)
        s = jnp.where((ki[None, :] <= qi[:, None])[None, None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", a, v)


def _chunked_attn(q, k, v, causal):
    """Online-softmax attention, lax.scan over KV chunks (flash-style in XLA).

    Keeps the baseline memory roofline term honest for 32k-token prefill:
    no [Sq, Sk] score tensor is ever materialized beyond a chunk.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    ck = min(ATTN_CHUNK, Sk)
    if Sk % ck:  # pad KV to a chunk multiple; padded keys are masked below
        pad = ck - Sk % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = k.shape[1] // ck
    scale = D ** -0.5
    kc = k.reshape(B, nk, ck, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ck, H, D).transpose(1, 0, 2, 3, 4)
    qi = jnp.arange(Sq)

    def body(carry, kv):
        (acc, m, l), (kb, vb, j) = carry, kv
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        ki = j * ck + jnp.arange(ck)
        if causal:
            s = jnp.where((ki[None, :] <= qi[:, None])[None, None], s, -1e30)
        else:
            s = jnp.where((ki < Sk)[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kc, vc, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention_fwd(p, h, cfg, *, positions, causal=True, kv=None):
    """Full-sequence attention (train / prefill).  Returns (out, (k, v))."""
    q, k, v = _project_qkv(p, h, cfg)
    if cfg.rope_theta > 0:
        cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta, h.dtype)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    cache_kv = (k, v)
    if kv is not None:  # cross-attention: use provided memory k/v
        k, v = kv
        cache_kv = kv
        causal = False
    k2, v2 = _repeat_kv(k, v, cfg)
    q = constraint(q, ("batch", None, "heads", None))
    if max(q.shape[1], k2.shape[1]) <= EXACT_ATTN_MAX_SEQ:
        out = _exact_attn(q, k2, v2, causal)
    else:
        out = _chunked_attn(q, k2, v2, causal)
    out = out.reshape(*h.shape[:2], cfg.num_heads * cfg.head_dim)
    return out @ p["wo"], cache_kv


def attention_decode(p, h, cfg, cache_k, cache_v, pos):
    """Single-token decode. h [B,1,D]; cache [B,Smax,KV,hd]; pos scalar int.

    The cache write is a one-hot select rather than dynamic-update-slice: DUS
    on the sequence-sharded cache makes GSPMD all-gather the whole cache every
    step (66 GB/step measured for llama3 decode_32k); the one-hot form is
    elementwise on the sharded dim so each shard updates locally.  The cost is
    a full cache rewrite (decode is HBM-bound regardless); the shard_map
    in-place variant is the hillclimbed version (distributed/collectives.py).
    """
    q, k, v = _project_qkv(p, h, cfg)
    if cfg.rope_theta > 0:
        posv = jnp.full((h.shape[0], 1), pos, jnp.int32)
        cos, sin = rope_tables(posv, cfg.head_dim, cfg.rope_theta, h.dtype)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    from repro.distributed import collectives, sharding as shd
    mesh = shd.active_mesh()
    if mesh is not None and collectives.applicable(
            mesh, h.shape[0], cache_k.shape[1], cfg.num_heads, cfg.num_kv_heads):
        out, cache_k, cache_v = collectives.flash_decode_attention(
            q, cache_k, cache_v, k, v, pos, mesh)
    else:
        sel = (jnp.arange(cache_k.shape[1]) == pos)[None, :, None, None]
        cache_k = jnp.where(sel, k.astype(cache_k.dtype), cache_k)
        cache_v = jnp.where(sel, v.astype(cache_v.dtype), cache_v)
        kk, vv = _repeat_kv(cache_k.astype(h.dtype), cache_v.astype(h.dtype), cfg)
        out = _exact_attn(q, kk, vv, causal=False, kv_len=jnp.asarray(pos + 1))
    out = out.reshape(h.shape[0], 1, cfg.num_heads * cfg.head_dim)
    return out @ p["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w1": PD((d, f), ("embed", "ff")),
        "w3": PD((d, f), ("embed", "ff")),
        "w2": PD((f, d), ("ff", "embed")),
    }


def mlp_fwd(p, h):
    g = jax.nn.silu(h @ p["w1"]) * (h @ p["w3"])
    return g @ p["w2"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_defs(cfg):
    v = cfg.padded_vocab
    defs = {"embedding": PD((v, cfg.d_model), ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        defs["unembed"] = PD((cfg.d_model, v), ("embed", "vocab"))
    return defs


def embed_fwd(p, tokens, dtype):
    return p["embedding"].astype(dtype)[tokens]


def unembed_fwd(p, h):
    w = p.get("unembed")
    if w is None:
        w = p["embedding"].T.astype(h.dtype)
    logits = (h @ w).astype(jnp.float32)
    # vocab-sharded logits: keeps the [V, D] unembedding gradient from being
    # materialized replicated (1.6 GB f32 per device for mistral-123b).
    return constraint(logits, ("batch", None, "vocab"))


def cross_entropy(logits, labels, mask=None):
    """logits [B,S,V] fp32, labels [B,S] int32; mean NLL over valid tokens."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
