"""InternVL2-style VLM backbone (InternLM2 decoder over patch + text embeds).

The InternViT frontend is STUBBED per the assignment: ``input_specs`` provides
precomputed patch embeddings [B, num_patches, d_model] which are concatenated
ahead of text-token embeddings; the combined sequence runs through the decoder
stack causally.  Loss is masked to text positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T


def model_defs(cfg):
    from repro.models.layers import PD
    defs = T.model_defs(cfg)
    # small projection applied to stub patch embeddings (stands in for the
    # mlp1 projector of InternVL2)
    defs["patch_proj"] = PD((cfg.d_model, cfg.d_model), ("embed", None))
    return defs


def _combine(params, patches, tokens, cfg):
    dtype = cfg.jnp_dtype
    pe = (patches.astype(dtype) @ params["patch_proj"]).astype(dtype)
    te = L.embed_fwd(params["embed"], tokens, dtype)
    return jnp.concatenate([pe, te], axis=1)


def forward(params, patches, tokens, cfg):
    h = _combine(params, patches, tokens, cfg)
    positions = jnp.arange(h.shape[1])[None, :]

    def body(h, bp):
        return T.block_fwd(bp, h, cfg, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    return L.rmsnorm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg):
    h = forward(params, batch["patches"], batch["tokens"], cfg)
    P = batch["patches"].shape[1]
    logits = L.unembed_fwd(params["embed"], h[:, P:])
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg, batch, max_seq, dtype):
    return T.init_cache(cfg, batch, max_seq, dtype)


def cache_logical(cfg):
    return T.cache_logical(cfg)


def prefill(params, patches, tokens, cfg, max_seq):
    """Prompt = patches + text; cache covers the combined sequence."""
    h = _combine(params, patches, tokens, cfg)
    positions = jnp.arange(h.shape[1])[None, :]

    def body(h, bp):
        bp = L.fsdp_gather(bp, T.block_defs(cfg))
        a, (k, v) = L.attention_fwd(
            bp["attn"], L.rmsnorm(h, bp["attn_norm"], cfg.norm_eps), cfg,
            positions=positions)
        h = h + a
        h = h + L.mlp_fwd(bp["mlp"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps))
        return h, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, (k_all, v_all) = jax.lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], h[:, -1:])
    pad = max_seq - h.shape[1]
    cache = {
        "k": jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
    }
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg):
    return T.decode_step(params, cache, tokens, pos, cfg)
