"""Jamba-style hybrid: Mamba+attention 1:7 interleave with MoE FFNs.

The layer stack is periodic (period = ``attn_period``): one attention mixer per
period (at ``attn_offset``), SSD mixers elsewhere; MoE FFN every
``moe_every``-th position, dense FFN otherwise.  We scan over periods (HLO size
is period-sized, not depth-sized); within the scan body the 8 sublayers are an
unrolled static loop over the period layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import PD
from repro.models.transformer import stacked


def layout(cfg):
    """[(mixer, ffn)] per position in one period."""
    out = []
    for i in range(cfg.attn_period):
        mixer = "attn" if i == cfg.attn_offset else "mamba"
        ffn = "moe" if (cfg.num_experts and i % cfg.moe_every == 1) else "dense"
        out.append((mixer, ffn))
    return out


def _pos_defs(cfg, mixer, ffn):
    d = {"mixer_norm": PD((cfg.d_model,), ("embed",), "ones"),
         "ffn_norm": PD((cfg.d_model,), ("embed",), "ones")}
    d["mixer"] = L.attention_defs(cfg) if mixer == "attn" else S.ssd_defs(cfg)
    d["ffn"] = M.moe_defs(cfg) if ffn == "moe" else L.mlp_defs(cfg)
    return d


def model_defs(cfg):
    n_periods = cfg.num_layers // cfg.attn_period
    periods = {
        f"pos{i}": stacked(_pos_defs(cfg, mixer, ffn), n_periods)
        for i, (mixer, ffn) in enumerate(layout(cfg))
    }
    return {
        "embed": L.embed_defs(cfg),
        "periods": periods,
        "final_norm": PD((cfg.d_model,), ("embed",), "ones"),
    }


def _apply_pos(p, h, cfg, mixer, ffn, positions):
    p = L.fsdp_gather(p, _pos_defs(cfg, mixer, ffn))
    hn = L.rmsnorm(h, p["mixer_norm"], cfg.norm_eps)
    if mixer == "attn":
        a, _ = L.attention_fwd(p["mixer"], hn, cfg, positions=positions)
    else:
        a = S.ssd_block_fwd(p["mixer"], hn, cfg)
    h = h + a
    hn = L.rmsnorm(h, p["ffn_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if ffn == "moe":
        f, aux = M.moe_fwd(p["ffn"], hn, cfg)
    else:
        f = L.mlp_fwd(p["ffn"], hn)
    return constraint(h + f, ("batch", "seq_sp", None)), aux


def forward(params, tokens, cfg):
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)
    positions = jnp.arange(tokens.shape[1])[None, :]
    lay = layout(cfg)

    def body(carry, period_params):
        h, aux = carry
        for i, (mixer, ffn) in enumerate(lay):
            h, a = _apply_pos(period_params[f"pos{i}"], h, cfg, mixer, ffn, positions)
            aux = aux + a
        return (h, aux), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["periods"])
    return L.rmsnorm(h, params["final_norm"], cfg.norm_eps), aux / cfg.num_layers


def loss_fn(params, batch, cfg, aux_weight=0.01):
    h, aux = forward(params, batch["tokens"], cfg)
    logits = L.unembed_fwd(params["embed"], h)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask")) + aux_weight * aux


# ---------------------------------------------------------------------------
# Serving: attention positions carry KV caches; mamba positions carry states
# ---------------------------------------------------------------------------

def init_cache(cfg, batch, max_seq, dtype):
    n_periods = cfg.num_layers // cfg.attn_period
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    n_mamba = sum(1 for m, _ in layout(cfg) if m == "mamba")
    cdt = jnp.dtype(cfg.cache_dtype)
    return {
        "k": jnp.zeros((n_periods, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cdt),
        "v": jnp.zeros((n_periods, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cdt),
        "conv": jnp.zeros((n_periods, n_mamba, batch, S.CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((n_periods, n_mamba, batch, cfg.ssm_nheads,
                          cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
    }


def cache_logical(cfg):
    return {
        "k": ("layers", "batch", "seq_kv", "kv_heads", None),
        "v": ("layers", "batch", "seq_kv", "kv_heads", None),
        "conv": ("layers", None, "batch", None, "ssm_inner"),
        "ssm": ("layers", None, "batch", "ssm_heads", None, None),
    }


def decode_step(params, cache, tokens, pos, cfg):
    # caches/states in the scan carry -> in-place (see transformer.decode_step)
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)
    lay = layout(cfg)

    def body(carry, period_params):
        h, ck_all, cv_all, conv_all, ssm_all, pi = carry
        mi = 0
        for i, (mixer, ffn) in enumerate(lay):
            p = L.fsdp_gather(period_params[f"pos{i}"], _pos_defs(cfg, mixer, ffn))
            hn = L.rmsnorm(h, p["mixer_norm"], cfg.norm_eps)
            if mixer == "attn":
                ck = jax.lax.dynamic_index_in_dim(ck_all, pi, 0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(cv_all, pi, 0, keepdims=False)
                a, ck, cv = L.attention_decode(p["mixer"], hn, cfg, ck, cv, pos)
                ck_all = jax.lax.dynamic_update_slice_in_dim(ck_all, ck[None], pi, 0)
                cv_all = jax.lax.dynamic_update_slice_in_dim(cv_all, cv[None], pi, 0)
            else:
                conv = jax.lax.dynamic_index_in_dim(
                    jax.lax.dynamic_index_in_dim(conv_all, pi, 0, keepdims=False),
                    mi, 0, keepdims=False)
                ssm = jax.lax.dynamic_index_in_dim(
                    jax.lax.dynamic_index_in_dim(ssm_all, pi, 0, keepdims=False),
                    mi, 0, keepdims=False)
                a, c_i, s_i = S.ssd_decode_step(p["mixer"], hn, cfg, conv, ssm)
                conv_all = jax.lax.dynamic_update_slice(
                    conv_all, c_i[None, None], (pi, mi, 0, 0, 0))
                ssm_all = jax.lax.dynamic_update_slice(
                    ssm_all, s_i[None, None], (pi, mi, 0, 0, 0, 0))
                mi += 1
            h = h + a
            hn = L.rmsnorm(h, p["ffn_norm"], cfg.norm_eps)
            if ffn == "moe":
                f, _ = M.moe_fwd(p["ffn"], hn, cfg)
            else:
                f = L.mlp_fwd(p["ffn"], hn)
            h = h + f
        return (h, ck_all, cv_all, conv_all, ssm_all, pi + 1), None

    (h, ck_all, cv_all, conv_all, ssm_all, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], cache["conv"], cache["ssm"],
               jnp.int32(0)), params["periods"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], h)
    return logits, {"k": ck_all, "v": cv_all, "conv": conv_all, "ssm": ssm_all}


def prefill(params, tokens, cfg, max_seq):
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)
    positions = jnp.arange(tokens.shape[1])[None, :]
    lay = layout(cfg)
    B, Sq = tokens.shape

    def body(h, period_params):
        ks = vs = None
        states = []
        for i, (mixer, ffn) in enumerate(lay):
            p = L.fsdp_gather(period_params[f"pos{i}"], _pos_defs(cfg, mixer, ffn))
            hn = L.rmsnorm(h, p["mixer_norm"], cfg.norm_eps)
            if mixer == "attn":
                a, (ks, vs) = L.attention_fwd(p["mixer"], hn, cfg, positions=positions)
            else:
                a, st = S.ssd_block_fwd(p["mixer"], hn, cfg, return_state=True)
                states.append(st)
            h = h + a
            hn = L.rmsnorm(h, p["ffn_norm"], cfg.norm_eps)
            f = M.moe_fwd(p["ffn"], hn, cfg)[0] if ffn == "moe" else L.mlp_fwd(p["ffn"], hn)
            h = constraint(h + f, ("batch", "seq_sp", None))
        return h, (ks, vs, jnp.stack(states))

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, (k_all, v_all, ssm_all) = jax.lax.scan(body, h, params["periods"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], h[:, -1:])
    pad = max_seq - Sq
    n_periods = cfg.num_layers // cfg.attn_period
    n_mamba = sum(1 for m, _ in lay if m == "mamba")
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    cache = {
        "k": jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "conv": jnp.zeros((n_periods, n_mamba, B, S.CONV_K - 1, conv_dim), cfg.jnp_dtype),
        "ssm": ssm_all.astype(jnp.float32),
    }
    return logits, cache
