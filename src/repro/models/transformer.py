"""Dense decoder-only transformer (llama3 / mistral / qwen family).

Layers are stacked on a leading ``layers`` axis and driven by ``lax.scan`` so
HLO size (and compile time) is depth-independent; each block is optionally
``jax.checkpoint``-ed (remat) so the 4k-train activations fit HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models.layers import PD


def block_defs(cfg):
    return {
        "attn_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "attn": L.attention_defs(cfg),
        "mlp_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "mlp": L.mlp_defs(cfg),
    }


def stacked(defs, n):
    return jax.tree.map(
        lambda pd: PD((n,) + pd.shape, ("layers",) + pd.logical, pd.init, pd.scale),
        defs, is_leaf=lambda x: isinstance(x, PD))


def model_defs(cfg):
    return {
        "embed": L.embed_defs(cfg),
        "blocks": stacked(block_defs(cfg), cfg.num_layers),
        "final_norm": PD((cfg.d_model,), ("embed",), "ones"),
    }


def block_fwd(p, h, cfg, positions):
    p = L.fsdp_gather(p, block_defs(cfg))
    a, _ = L.attention_fwd(p["attn"], L.rmsnorm(h, p["attn_norm"], cfg.norm_eps),
                           cfg, positions=positions)
    h = h + a
    h = constraint(h, ("batch", "seq_sp", None))
    m = L.mlp_fwd(p["mlp"], L.rmsnorm(h, p["mlp_norm"], cfg.norm_eps))
    h = h + m
    return constraint(h, ("batch", "seq_sp", None))


def forward(params, tokens, cfg):
    """tokens [B,S] -> hidden [B,S,D] (pre-unembed)."""
    dtype = cfg.jnp_dtype
    h = L.embed_fwd(params["embed"], tokens, dtype)
    h = constraint(h, ("batch", "seq_sp", None))
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(h, bp):
        return block_fwd(bp, h, cfg, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["blocks"])
    else:
        for i in range(cfg.num_layers):
            h, _ = body(h, jax.tree.map(lambda x: x[i], params["blocks"]))
    return L.rmsnorm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg):
    h = forward(params, batch["tokens"], cfg)
    logits = L.unembed_fwd(params["embed"], h)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch, max_seq, dtype):
    del dtype  # storage dtype comes from cfg (fp8 KV quantization for MHA)
    cdt = jnp.dtype(cfg.cache_dtype)
    kv = {
        "k": jnp.zeros((cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cdt),
        "v": jnp.zeros((cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cdt),
    }
    return kv


def cache_logical(cfg):
    ax = ("layers", "batch", "seq_kv", "kv_heads", None)
    return {"k": ax, "v": ax}


def prefill(params, tokens, cfg, max_seq):
    """Run the full prompt; return (last-position logits, filled cache)."""
    dtype = cfg.jnp_dtype
    B, S = tokens.shape
    h = L.embed_fwd(params["embed"], tokens, dtype)
    positions = jnp.arange(S)[None, :]
    ks, vs = [], []

    def body(h, bp):
        bp = L.fsdp_gather(bp, block_defs(cfg))
        a, (k, v) = L.attention_fwd(
            bp["attn"], L.rmsnorm(h, bp["attn_norm"], cfg.norm_eps), cfg,
            positions=positions)
        h = h + a
        h = h + L.mlp_fwd(bp["mlp"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps))
        return constraint(h, ("batch", "seq_sp", None)), (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, (k_all, v_all) = jax.lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], h[:, -1:])
    pad = max_seq - S
    cache = {
        "k": jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
    }
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg):
    """tokens [B,1]; pos scalar int32 (current position). Returns (logits, cache).

    The cache lives in the scan CARRY (xs->ys scanning double-buffers it, and
    unrolled chained updates interleaved with shard_map leave ~3x cache copies
    in temps — both measured).  Carry + dynamic_update_slice aliases to zero
    temp overhead; the per-layer slice passes through the shard_map
    flash-decode (distributed/collectives.py) which updates it in place.
    """
    dtype = cfg.jnp_dtype
    h = L.embed_fwd(params["embed"], tokens, dtype)

    def body(carry, bp):
        h, ck_all, cv_all, i = carry
        bp = L.fsdp_gather(bp, block_defs(cfg))
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        a, ck, cv = L.attention_decode(
            bp["attn"], L.rmsnorm(h, bp["attn_norm"], cfg.norm_eps), cfg, ck, cv, pos)
        ck_all = jax.lax.dynamic_update_slice_in_dim(ck_all, ck[None], i, 0)
        cv_all = jax.lax.dynamic_update_slice_in_dim(cv_all, cv[None], i, 0)
        h = h + a
        h = h + L.mlp_fwd(bp["mlp"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps))
        return (h, ck_all, cv_all, i + 1), None

    (h, ck_all, cv_all, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], jnp.int32(0)), params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], h)
    return logits, {"k": ck_all, "v": cv_all}
