"""Mixture-of-Experts FFN (dbrx / granite / jamba) with sort-based dispatch.

Dispatch is capacity-bucketed (Switch-style) so all shapes are static and
FLOPs stay proportional to *active* experts: tokens are argsorted by expert id,
each expert keeps at most ``capacity`` tokens, the rest are dropped (their
combine weight is zero, residual passes through).  Logical sharding:
``expert`` -> EP over the model axis when num_experts divides it (dbrx 16/16),
otherwise falls back and ``expert_ff`` TP-shards each expert's hidden dim
(granite: 40 experts, d_ff 512/16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import compat_shard_map, constraint
from repro.models import layers as L
from repro.models.layers import PD

CAPACITY_FACTOR = 1.25


def moe_defs(cfg, d_ff=None):
    d, f, e = cfg.d_model, d_ff or cfg.d_ff, cfg.num_experts
    return {
        "router": PD((d, e), ("embed", None)),
        "w1": PD((e, d, f), ("expert", "embed", "expert_ff")),
        "w3": PD((e, d, f), ("expert", "embed", "expert_ff")),
        "w2": PD((e, f, d), ("expert", "expert_ff", "embed")),
    }


def capacity(num_tokens, cfg):
    c = int(num_tokens * cfg.experts_per_token / cfg.num_experts * CAPACITY_FACTOR)
    # round to 64 so the capacity dim stays shardable over dp(+tp) axes; the
    # logical rules degrade gracefully (drop axes) when it does not divide.
    return max(64, -(-c // 64) * 64)


def _dispatch(x, router, cfg, C):
    """Local sort-based dispatch.  x [T,D] -> (xe [E,C,D], combine closure, aux).

    Tokens are argsorted by expert id and bucketed with fixed capacity C; the
    scatter uses drop-mode out-of-range indices so no +1 pad rows are needed.
    """
    T, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    probs = jax.nn.softmax((x @ router).astype(jnp.float32), axis=-1)  # [T,E]
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e frac_tokens_e * mean_prob_e
    me = probs.mean(0)
    ce = jnp.zeros(E).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros(E, jnp.int32).at[se].add(1)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * K) - offsets[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)          # E*C -> dropped

    buf_tok = jnp.zeros((E * C,), jnp.int32).at[slot].set(
        jnp.where(keep, st, 0), mode="drop").reshape(E, C)
    xe = jnp.take(x, buf_tok, axis=0, mode="clip")            # [E, C, D]

    buf_w = jnp.zeros((E * C,), flat_w.dtype).at[slot].set(
        jnp.where(keep, sw, 0.0), mode="drop")
    buf_src = jnp.full((E * C,), T, jnp.int32).at[slot].set(
        jnp.where(keep, st, T), mode="drop")

    def combine(ye):
        out = jnp.zeros((T, D), jnp.float32)
        upd = ye.reshape(E * C, D).astype(jnp.float32) * buf_w[:, None]
        return out.at[buf_src].add(upd, mode="drop").astype(x.dtype)

    return xe, combine, aux


def _expert_ffn(xe, w1, w3, w2):
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1))
    g = g * jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", g, w2)


def moe_fwd(p, h, cfg):
    """h [B,S,D] -> ([B,S,D], aux_loss).

    Distribution: GSPMD cannot partition the data-dependent dispatch
    gather/scatter (it replicates [T,D]-sized f32 buffers per device —
    measured 6 GB x13 for dbrx train), so under an active mesh the MoE runs in
    ``jax.shard_map``: dispatch is *local* to each data shard, then either
      * EP (num_experts % model == 0, dbrx/jamba): all-to-all over the model
        axis moves capacity buckets to their expert's device and back, or
      * expert-TP (granite): every device holds a d_ff shard of every expert;
        partial results psum over the model axis.
    Without a mesh (unit tests) the same dispatch runs locally in full.
    """
    from repro.distributed.sharding import active_mesh
    mesh = active_mesh()
    B, S, D = h.shape
    if mesh is None:
        xe, combine, aux = _dispatch(
            h.reshape(B * S, D), p["router"], cfg, capacity(B * S, cfg))
        return combine(_expert_ffn(xe, p["w1"], p["w3"], p["w2"])).reshape(B, S, D), aux

    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in axes)
    while dp and B % _size(axes, dp) != 0:
        dp = dp[1:]          # long_500k decode (B=1): replicate over data
    ep = axes.get("model", 1)
    use_ep = cfg.num_experts % ep == 0
    # EP wants tokens sharded over the model axis too (each device dispatches
    # a distinct token slice; the all-to-all then carries no duplicates).
    # Expert-TP instead *requires* token replication over model (each device
    # holds a d_ff shard of every expert; psum adds the partial outputs).
    seq_model = "model" if (use_ep and S % ep == 0) else None
    P_ = jax.sharding.PartitionSpec
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    S_local = S // (ep if seq_model else 1)
    T_local = (B // max(_size(axes, dp), 1)) * S_local
    C = capacity(T_local, cfg)
    E = cfg.num_experts

    def body(hl, router, w1, w3, w2):
        Bl, Sl = hl.shape[0], hl.shape[1]
        x = hl.reshape(Bl * Sl, D)
        xe, combine, aux = _dispatch(x, router, cfg, C)
        if use_ep:
            # [E, C, D] -> [E/ep, C*ep, D]: capacity buckets travel to experts
            xe = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=1,
                                    tiled=True)
            ye = _expert_ffn(xe, w1, w3, w2)
            ye = jax.lax.all_to_all(ye, "model", split_axis=1, concat_axis=0,
                                    tiled=True)
        else:
            # expert-TP: local d_ff shard of every expert, psum partial outputs
            ye = jax.lax.psum(_expert_ffn(xe, w1, w3, w2), "model")
        out = combine(ye).reshape(Bl, Sl, D)
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
        if seq_model:
            aux = jax.lax.pmean(aux, "model")
        return out, aux

    if use_ep:
        w13_spec = w2_spec = P_("model", None, None)
    else:  # w1/w3 are [E, D, F], w2 is [E, F, D]: shard the F dim of each
        w13_spec = P_(None, None, "model")
        w2_spec = P_(None, "model", None)
    out, aux = compat_shard_map(
        body, mesh=mesh,
        in_specs=(P_(dp_spec, seq_model, None), P_(None, None),
                  w13_spec, w13_spec, w2_spec),
        out_specs=(P_(dp_spec, seq_model, None), P_()),
        check_vma=False,
    )(h, p["router"], p["w1"], p["w3"], p["w2"])
    return out, aux


def _size(axes, names):
    n = 1
    for a in names:
        n *= axes[a]
    return n


# ---------------------------------------------------------------------------
# MoE transformer (dbrx / granite): attention + MoE FFN blocks
# ---------------------------------------------------------------------------

def block_defs(cfg):
    return {
        "attn_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "attn": L.attention_defs(cfg),
        "mlp_norm": PD((cfg.d_model,), ("embed",), "ones"),
        "moe": moe_defs(cfg),
    }


def model_defs(cfg):
    from repro.models.transformer import stacked
    return {
        "embed": L.embed_defs(cfg),
        "blocks": stacked(block_defs(cfg), cfg.num_layers),
        "final_norm": PD((cfg.d_model,), ("embed",), "ones"),
    }


def block_fwd(p, h, cfg, positions):
    p = L.fsdp_gather(p, block_defs(cfg))
    a, _ = L.attention_fwd(p["attn"], L.rmsnorm(h, p["attn_norm"], cfg.norm_eps),
                           cfg, positions=positions)
    h = h + a
    m, aux = moe_fwd(p["moe"], L.rmsnorm(h, p["mlp_norm"], cfg.norm_eps), cfg)
    return constraint(h + m, ("batch", "seq_sp", None)), aux


def forward(params, tokens, cfg):
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(carry, bp):
        h, aux = carry
        h, a = block_fwd(bp, h, cfg, positions)
        return (h, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["blocks"])
    return L.rmsnorm(h, params["final_norm"], cfg.norm_eps), aux / cfg.num_layers


def loss_fn(params, batch, cfg, aux_weight=0.01):
    h, aux = forward(params, batch["tokens"], cfg)
    logits = L.unembed_fwd(params["embed"], h)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask")) + aux_weight * aux


def init_cache(cfg, batch, max_seq, dtype):
    from repro.models import transformer
    return transformer.init_cache(cfg, batch, max_seq, dtype)


def cache_logical(cfg):
    from repro.models import transformer
    return transformer.cache_logical(cfg)


def decode_step(params, cache, tokens, pos, cfg):
    # cache in scan carry -> in-place updates (see transformer.decode_step)
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)

    def body(carry, bp):
        h, ck_all, cv_all, i = carry
        bp = L.fsdp_gather(bp, block_defs(cfg))
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        a, ck, cv = L.attention_decode(
            bp["attn"], L.rmsnorm(h, bp["attn_norm"], cfg.norm_eps), cfg, ck, cv, pos)
        ck_all = jax.lax.dynamic_update_slice_in_dim(ck_all, ck[None], i, 0)
        cv_all = jax.lax.dynamic_update_slice_in_dim(cv_all, cv[None], i, 0)
        h = h + a
        m, _ = moe_fwd(bp["moe"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps), cfg)
        return (h + m, ck_all, cv_all, i + 1), None

    (h, ck_all, cv_all, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], jnp.int32(0)), params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.unembed_fwd(params["embed"], h), {"k": ck_all, "v": cv_all}


def prefill(params, tokens, cfg, max_seq):
    h = L.embed_fwd(params["embed"], tokens, cfg.jnp_dtype)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(h, bp):
        bp = L.fsdp_gather(bp, block_defs(cfg))
        a, (k, v) = L.attention_fwd(
            bp["attn"], L.rmsnorm(h, bp["attn_norm"], cfg.norm_eps), cfg,
            positions=positions)
        h = h + a
        m, _ = moe_fwd(bp["moe"], L.rmsnorm(h, bp["mlp_norm"], cfg.norm_eps), cfg)
        return constraint(h + m, ("batch", "seq_sp", None)), (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, (k_all, v_all) = jax.lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_fwd(params["embed"], h[:, -1:])
    pad = max_seq - tokens.shape[1]
    cache = {
        "k": jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
    }
    return logits, cache
