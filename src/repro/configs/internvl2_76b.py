"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

InternViT + InternLM2 [arXiv:2404.16821; unverified].  ViT frontend STUBBED:
input_specs() provides precomputed patch embeddings (num_patches x d_model) that the
backbone concatenates with text-token embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, num_patches=256,
))
