"""The paper's Table-10 evaluation environment: 24 vector-engine configs.

Configs 1-24: MVL in {8,16,32,64,128,256} 64-bit elements x lanes in {1,2,4,8},
renaming with 40 physical registers, in-order issue queues, one pipelined
arithmetic unit per lane, one memory port into L2, ring interconnect —
exactly the §5 sweep.  ``TABLE10[i]`` is config i+1.

The memory-hierarchy variants are first-class batched studies: the Fig-10
LLC grid (``TABLE10_L2_1MB``) and the MSHR saturation grid
(``TABLE10_MSHR1``) run through the same compiled scan as the base grid —
``engine.VectorEngineConfig.label()`` keeps their result keys distinct.
"""
from __future__ import annotations

import dataclasses

from repro.core.engine import VectorEngineConfig

MVLS = (8, 16, 32, 64, 128, 256)
LANES = (1, 2, 4, 8)

TABLE10 = tuple(
    VectorEngineConfig(
        mvl=mvl, lanes=lanes, phys_regs=40, queue_entries=16,
        ooo_issue=False, vrf_read_ports=1, vrf_line_bits=512,
        interconnect="ring", mem_ports=1, cache_line_bits=512,
        lat_l1=4.0, lat_l2=12.0, l2_kb=256,
        scalar_freq_ghz=2.0, vector_freq_ghz=1.0, scalar_ipc=2.0,
    )
    for mvl in MVLS for lanes in LANES
)

# §5.7's second memory system: 1 MB LLC (Fig 10)
TABLE10_L2_1MB = tuple(
    dataclasses.replace(cfg, l2_kb=1024) for cfg in TABLE10
)

# MSHR saturation study: a single miss-status register serializes every
# demand (indexed/gather) miss — the knob the memory model makes live
TABLE10_MSHR1 = tuple(
    dataclasses.replace(cfg, mshrs=1) for cfg in TABLE10
)
