"""The paper's Table-10 evaluation environment: 24 vector-engine configs.

Configs 1-24: MVL in {8,16,32,64,128,256} 64-bit elements x lanes in {1,2,4,8},
renaming with 40 physical registers, in-order issue queues, one pipelined
arithmetic unit per lane, one memory port into L2, ring interconnect —
exactly the §5 sweep.  ``TABLE10[i]`` is config i+1.

The memory-hierarchy variants are first-class batched studies: the Fig-10
LLC grid (``TABLE10_L2_1MB``) and the MSHR saturation grid
(``TABLE10_MSHR1``) run through the same compiled scan as the base grid —
``engine.VectorEngineConfig.label()`` keeps their result keys distinct.

Beyond the fixed grids, the design-space exploration spaces
(``SPACE_SMOKE`` / ``SPACE_QUICK`` / ``SPACE_FULL``) declare the *live* knob
ranges the DSE engine (``repro.core.dse``) enumerates, shards across
devices, and reduces to Pareto frontiers.  Every axis below is a traced
engine parameter, so the whole space reuses one compiled scan.
"""
from __future__ import annotations

import dataclasses

from repro.core.dse import DesignSpace
from repro.core.engine import VectorEngineConfig

MVLS = (8, 16, 32, 64, 128, 256)
LANES = (1, 2, 4, 8)

# The RVV-assembly-sourced suite variant: the same seven RiVec apps with
# loop bodies *decoded from src/repro/asm* (repro.core.rvv) instead of the
# hand-coded tracegen bodies.  The ":asm" names resolve through
# tracegen.body_for/chunks_for, so they ride suite.sweep_all, the golden
# table and dse.explore exactly like the plain names.
from repro.core.tracegen import ASM_APPS as ASM_SUITE  # noqa: E402

TABLE10 = tuple(
    VectorEngineConfig(
        mvl=mvl, lanes=lanes, phys_regs=40, queue_entries=16,
        ooo_issue=False, vrf_read_ports=1, vrf_line_bits=512,
        interconnect="ring", mem_ports=1, cache_line_bits=512,
        lat_l1=4.0, lat_l2=12.0, l2_kb=256,
        scalar_freq_ghz=2.0, vector_freq_ghz=1.0, issue_width=2,
    )
    for mvl in MVLS for lanes in LANES
)

# §5.7's second memory system: 1 MB LLC (Fig 10)
TABLE10_L2_1MB = tuple(
    dataclasses.replace(cfg, l2_kb=1024) for cfg in TABLE10
)

# MSHR saturation study: a single miss-status register serializes every
# demand (indexed/gather) miss — the knob the memory model makes live
TABLE10_MSHR1 = tuple(
    dataclasses.replace(cfg, mshrs=1) for cfg in TABLE10
)

# ---------------------------------------------------------------------------
# DSE spaces (repro.core.dse): embedded short-vector -> HPC long-vector.
#
# SPACE_FULL is the headline design space — the Table-10 grid crossed with
# renaming depth, issue-queue size, issue policy, LLC capacity, MSHR file
# and DRAM bandwidth: 6*4*2*2*2*2*2*2 = 1536 configurations.  SPACE_QUICK
# (384) is the single-device acceptance sweep (`benchmarks/run.py --dse
# --quick`); SPACE_SMOKE (64) is the CI cache/dedup gate.
# ---------------------------------------------------------------------------

SPACE_FULL = DesignSpace.of(
    "full",
    mvl=MVLS,                        # 6
    lanes=LANES,                     # 4
    phys_regs=(40, 64),              # 2  renaming depth
    queue_entries=(8, 16),           # 2  issue-queue size
    ooo_issue=(False, True),         # 2  issue policy
    l2_kb=(256, 1024),               # 2  Fig-10 LLC axis
    mshrs=(1, 16),                   # 2  gather-miss concurrency
    dram_bw_bytes_cycle=(4.0, 8.0),  # 2  memory-system generation
)

SPACE_QUICK = DesignSpace.of(
    "quick",
    mvl=MVLS,                        # 6
    lanes=LANES,                     # 4
    ooo_issue=(False, True),         # 2
    l2_kb=(256, 1024),               # 2
    mshrs=(1, 16),                   # 2
    dram_bw_bytes_cycle=(4.0, 8.0),  # 2  -> 384 points (acceptance: >=256)
)

SPACE_SMOKE = DesignSpace.of(
    "smoke",
    mvl=(16, 64, 128, 256),
    lanes=(2, 8),
    l2_kb=(256, 1024),
    mshrs=(1, 16),
    dram_bw_bytes_cycle=(4.0, 8.0),
)

# ---------------------------------------------------------------------------
# Surrogate-search spaces (repro.core.search): beyond exhaustive reach.
#
# SPACE_HUGE is the million-point design space the learned surrogate makes
# tractable — every SPACE_FULL axis widened (lanes to 16, renaming to 96,
# three MSHR files, four LLCs, three DRAM generations) plus the knobs the
# exact sweeps never had the budget to open (ROB depth, VRF read ports,
# interconnect topology, memory ports, L1 capacity):
# 6*5*4*2*3*2*2*2*2*3*4*3*3 = 1,244,160 configurations.  Every SPACE_FULL
# point is a SPACE_HUGE point (each axis is a superset and every unlisted
# knob keeps its default), so the exhaustive SPACE_FULL Pareto frontier is a
# recall yardstick for the surrogate-guided search.
#
# SPACE_10K (18,432) is the CI-scale search space: big enough that the
# search layer's pruning matters, small enough to smoke-test in seconds.
# ---------------------------------------------------------------------------

SPACE_HUGE = DesignSpace.of(
    "huge",
    mvl=MVLS,                             # 6
    lanes=(1, 2, 4, 8, 16),               # 5  datapath width, past Table 10
    phys_regs=(40, 48, 64, 96),           # 4  renaming depth (96 = ring cap)
    rob_entries=(32, 64),                 # 2  reorder window
    queue_entries=(8, 16, 32),            # 3  issue-queue size
    ooo_issue=(False, True),              # 2  issue policy
    vrf_read_ports=(1, 2),                # 2  VRF port count (§3.2.4 startup)
    interconnect=("ring", "crossbar"),    # 2  slide/reduce topology (§3.2.6)
    mem_ports=(1, 2),                     # 2  L2 ports
    l1_kb=(16, 32, 64),                   # 3  private cache
    l2_kb=(256, 512, 1024, 2048),         # 4  LLC capacity
    mshrs=(1, 4, 16),                     # 3  gather-miss concurrency
    dram_bw_bytes_cycle=(4.0, 8.0, 16.0),  # 3  memory-system generation
)

SPACE_10K = DesignSpace.of(
    "10k",
    mvl=MVLS,                        # 6
    lanes=LANES,                     # 4
    phys_regs=(40, 64),              # 2
    rob_entries=(32, 64),            # 2
    queue_entries=(8, 16),           # 2
    ooo_issue=(False, True),         # 2
    vrf_read_ports=(1, 2),           # 2
    l1_kb=(16, 32, 64),              # 3
    l2_kb=(256, 1024),               # 2
    mshrs=(1, 16),                   # 2
    dram_bw_bytes_cycle=(4.0, 8.0),  # 2  -> 18,432 points
)

# Default app subsets per space: smoke pairs a compute-bound app with the
# gather-heavy one (exercises both memory paths), quick adds a frontend-only
# ML workload, full is the whole 10-app suite.
SPACE_PRESET_APPS = {
    "smoke": ("blackscholes", "canneal"),
    "quick": ("blackscholes", "canneal", "ssd_scan"),
    "full": None,  # explore() default: every registered app
}
