from repro.configs.base import (ARCH_IDS, SHAPES, InputShape, ModelConfig,
                                get_config, iter_cells, list_configs, register,
                                shape_applicable)
