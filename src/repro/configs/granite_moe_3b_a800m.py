"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].  The structured assignment line says
"MoE 40e top-8" while its free-text comment says 32 experts; we follow the structured
field (40 experts).  40 experts do not divide model=16 -> per-expert TP over d_ff
(512/16 = 32 per shard) instead of EP (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155, num_experts=40, experts_per_token=8,
))
