"""Config system: model configs, input shapes, mesh/run configs, and the registry.

Every assigned architecture registers a ``ModelConfig`` here (one module per arch
under ``repro.configs``).  Input shapes are the four assigned LM shape cells; the
dry-run enumerates ``(arch, shape)`` cells via :func:`iter_cells`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1          # MoE FFN every k-th layer (jamba: 2); dense otherwise
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256        # SSD chunk length (the "MVL" of the state scan)
    # --- hybrid (jamba) ---
    attn_period: int = 0        # one attention layer per `attn_period` layers; 0 = n/a
    attn_offset: int = 4
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    num_frames: int = 0         # stubbed conv frontend output length
    # --- VLM ---
    num_patches: int = 0        # stubbed ViT frontend output length
    # --- numerics / training ---
    dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"   # KV-cache storage (fp8 for MHA long-ctx)
    remat: bool = True
    scan_layers: bool = True
    # Layers with different shapes scanned per-period for hybrids.

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the vocab dim TP-shards
        (granite 49155 / whisper 51865 / mamba2 50280 are not divisible by the
        model axis; unsharded logits cost 12 GB/device for granite train).
        Labels are always < vocab_size; pad logits only dilute the softmax."""
        return -(-self.vocab_size // 128) * 128

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def attn_layers(self) -> int:
        if self.family == "hybrid":
            return self.num_layers // self.attn_period
        if self.family == "ssm":
            return 0
        return self.num_layers

    @property
    def is_subquadratic(self) -> bool:
        """True when the arch can decode 500k-token contexts (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=max(2, self.attn_period or 2) if self.family == "hybrid" else 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=2 if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16,
            ssm_chunk=8,
            encoder_layers=2 if self.encoder_layers else 0,
            num_frames=16 if self.num_frames else 0,
            num_patches=8 if self.num_patches else 0,
            dtype="float32",
            cache_dtype="float32",
            remat=False,
        )


# ---------------------------------------------------------------------------
# Input shape cells
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether a shape cell applies to an arch (per DESIGN.md §5 skips)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % cfg.name
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.family in FAMILIES, cfg.family
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


ARCH_IDS = (
    "llama3-8b",
    "mistral-large-123b",
    "qwen1.5-32b",
    "qwen2.5-3b",
    "whisper-small",
    "mamba2-130m",
    "dbrx-132b",
    "granite-moe-3b-a800m",
    "internvl2-76b",
    "jamba-v0.1-52b",
)

_MODULES = (
    "llama3_8b", "mistral_large_123b", "qwen1_5_32b", "qwen2_5_3b",
    "whisper_small", "mamba2_130m", "dbrx_132b", "granite_moe_3b_a800m",
    "internvl2_76b", "jamba_v0_1_52b",
)


def _load_all() -> None:
    import importlib
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


def iter_cells():
    """Yield (ModelConfig, InputShape, applicable, reason) for the 40 cells."""
    _load_all()
    for arch in ARCH_IDS:
        cfg = _REGISTRY[arch]
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            yield cfg, shape, ok, why
