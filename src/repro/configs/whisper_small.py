"""whisper-small [audio] — enc-dec, 12L d_model=768 12H d_ff=3072 vocab=51865.

Conv frontend STUBBED: input_specs() provides precomputed frame embeddings
(num_frames x d_model) [arXiv:2212.04356; unverified].  12 heads do not divide
model=16 -> replicated-attention fallback.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865, encoder_layers=12, num_frames=1500,
    rope_theta=0.0,  # whisper: absolute (sinusoidal) positions, no RoPE
))
