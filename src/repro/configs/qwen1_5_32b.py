"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40, i.e. MHA) d_ff=27392 vocab=152064.

QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].  NOTE: 40 heads do not divide the model=16
mesh axis; the sharding rules fall back to replicated attention + TP FFN (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152064, qkv_bias=True,
    # MHA (kv=40) makes the 32k-decode KV cache 21.5 GB/device even perfectly
    # sharded; fp8 KV-cache quantization (standard for MHA long-context
    # serving) brings it inside HBM.
    cache_dtype="float8_e4m3fn",
))
