"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.

Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf].
One attention layer per 8 (attn_period=8, offset 4), MoE FFN every 2nd layer,
mamba d_state=16 (Jamba uses Mamba-1 state size; we run our SSD block with N=16).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536, num_experts=16, experts_per_token=2, moe_every=2,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, attn_period=8, attn_offset=4,
))
