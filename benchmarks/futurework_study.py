"""Beyond-paper study: the knobs the paper lists but never evaluates (§7).

The paper's §3 describes configurable out-of-order issue, crossbar vs ring
interconnect, VRF read ports and memory ports, but §5 evaluates only the
in-order/ring/1-port design.  This study sweeps those knobs over the suite —
the experiments the paper proposes as future work, runnable here because the
engine model is jittable and cheap.

    PYTHONPATH=src python benchmarks/futurework_study.py
"""
from __future__ import annotations

import dataclasses

from repro.core import engine as eng
from repro.core import suite, tracegen

BASE = eng.VectorEngineConfig(mvl=64, lanes=4)

VARIANTS = {
    "baseline(in-order,ring,1rp,1mp)": {},
    "ooo_issue": {"ooo_issue": True},
    "crossbar": {"interconnect": "crossbar"},
    "vrf_3_read_ports": {"vrf_read_ports": 3},
    "2_mem_ports": {"mem_ports": 2},
    "all_upgrades": {"ooo_issue": True, "interconnect": "crossbar",
                     "vrf_read_ports": 3, "mem_ports": 2},
}


def main() -> None:
    apps = list(tracegen.APPS)
    print(f"{'variant':34s}" + "".join(f"{a[:10]:>11s}" for a in apps))
    base_speed = {}
    for name, kw in VARIANTS.items():
        cfg = dataclasses.replace(BASE, **kw)
        row = []
        for app in apps:
            s = suite.speedup(app, cfg)
            if name.startswith("baseline"):
                base_speed[app] = s
            row.append(s / base_speed[app])
        print(f"{name:34s}" + "".join(f"{r:11.3f}" for r in row))
    print("\n(values are speedup relative to the paper's evaluated design; "
          "MVL=64, 4 lanes)")


if __name__ == "__main__":
    main()
