"""Beyond-paper study: the knobs the paper lists but never evaluates (§7).

The paper's §3 describes configurable out-of-order issue, crossbar vs ring
interconnect, VRF read ports and memory ports, but §5 evaluates only the
in-order/ring/1-port design.  This study sweeps those knobs over the suite —
the experiments the paper proposes as future work, runnable here because the
engine model is jittable and cheap.

    PYTHONPATH=src python benchmarks/futurework_study.py [--quick]
"""
from __future__ import annotations

import dataclasses

from repro.core import engine as eng
from repro.core import suite, tracegen

BASE = eng.VectorEngineConfig(mvl=64, lanes=4)

VARIANTS = {
    "baseline(in-order,ring,1rp,1mp)": {},
    "ooo_issue": {"ooo_issue": True},
    "crossbar": {"interconnect": "crossbar"},
    "vrf_3_read_ports": {"vrf_read_ports": 3},
    "2_mem_ports": {"mem_ports": 2},
    "all_upgrades": {"ooo_issue": True, "interconnect": "crossbar",
                     "vrf_read_ports": 3, "mem_ports": 2},
}


def study(apps=None, variants=None) -> dict:
    """Speedup of each variant relative to the evaluated baseline design,
    per app — the whole (variant x app) grid as ONE batched dispatch set
    (it previously ran 60 sequential ``suite.speedup`` calls)."""
    apps = list(tracegen.APPS) if apps is None else list(apps)
    variants = dict(VARIANTS) if variants is None else dict(variants)
    pairs = [(app, dataclasses.replace(BASE, **kw))
             for kw in variants.values() for app in apps]
    flat = suite.speedup_batch(pairs)
    n = len(apps)
    rows = {name: dict(zip(apps, flat[i * n:(i + 1) * n]))
            for i, name in enumerate(variants)}
    # normalize to the named baseline wherever it sits in the dict
    base_name = next((k for k in variants if k.startswith("baseline")),
                     next(iter(variants)))
    base = rows[base_name]
    return {name: {a: s / base[a] for a, s in row.items()}
            for name, row in rows.items()}


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="two apps x three variants (driver smoke mode)")
    args = ap.parse_args(argv)
    apps = ["blackscholes", "jacobi-2d"] if args.quick else None
    variants = None
    if args.quick:
        variants = {k: VARIANTS[k] for k in
                    ("baseline(in-order,ring,1rp,1mp)", "ooo_issue",
                     "crossbar")}
    table = study(apps, variants)
    apps = list(next(iter(table.values())))
    print(f"{'variant':34s}" + "".join(f"{a[:10]:>11s}" for a in apps))
    for name, row in table.items():
        print(f"{name:34s}" + "".join(f"{row[a]:11.3f}" for a in apps))
    print("\n(values are speedup relative to the paper's evaluated design; "
          "MVL=64, 4 lanes)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
