"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  For the characterization tables
(3-9) `derived` is the max relative error vs the published cells; for the
scalability figures (4-10) it is the modeled speedup; for kernels it is
throughput; for the roofline it is the dominant term + roofline fraction.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _t(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


# machine-readable results collected while the driver runs; main() writes
# them to --bench-json (BENCH_pr10.json by default)
_BENCH: dict = {}


def table_3_to_9_characterization():
    from repro.core import characterize as ch
    rows = []
    for app in ch.PAPER_TABLES:
        t0 = time.perf_counter()
        errs = ch.compare_to_paper(app)
        us = (time.perf_counter() - t0) * 1e6
        worst = max(v for r in errs for k, v in r.items() if k.startswith("err"))
        rows.append((f"table_characterization_{app}", us, f"max_err={worst:.4f}"))
        vao = ch.characterize(app, 8).vao_speedup
        rows.append((f"vao_speedup_{app}", 0.0, f"{vao:.3f}"))
    return rows


def figures_4_to_10_scalability():
    """Figures 4-10 grid, one batched dispatch set instead of per-point sims."""
    from repro.core import engine as eng
    from repro.core import suite
    apps = ("blackscholes", "canneal", "jacobi-2d", "particlefilter",
            "pathfinder", "streamcluster", "swaptions")
    pairs = [(app, eng.VectorEngineConfig(mvl=mvl, lanes=lanes))
             for app in apps for mvl in (8, 64, 256) for lanes in (1, 8)]
    # Fig 10: swaptions LLC study rides in the same batch
    pairs += [("swaptions", eng.VectorEngineConfig(mvl=256, lanes=8, l2_kb=l2))
              for l2 in (256, 1024)]
    t0 = time.perf_counter()
    speedups = suite.speedup_batch(pairs)
    us_each = (time.perf_counter() - t0) * 1e6 / len(pairs)
    rows = []
    for (app, cfg), s in zip(pairs[:-2], speedups[:-2]):
        rows.append((f"fig_scalability_{app}_mvl{cfg.mvl}_l{cfg.lanes}",
                     us_each, f"speedup={s:.2f}"))
    for (app, cfg), s in zip(pairs[-2:], speedups[-2:]):
        rows.append((f"fig10_swaptions_l2_{cfg.l2_kb}kb", us_each,
                     f"speedup={s:.2f}"))
    return rows


def sweep_llc():
    """Fig-10 as a first-class batched study: the full LLC grid {256 KB, 1 MB}
    for the memory-stressed apps vs a compute-bound control, one batch."""
    from repro.core import engine as eng
    from repro.core import suite
    apps = ("streamcluster", "canneal", "swaptions", "blackscholes")
    l2s = (256, 1024)
    pairs = [(a, eng.VectorEngineConfig(mvl=mvl, lanes=8, l2_kb=l2))
             for a in apps for l2 in l2s for mvl in (64, 256)]
    t0 = time.perf_counter()
    vals = suite.speedup_batch(pairs)
    us_each = (time.perf_counter() - t0) * 1e6 / len(pairs)
    return [(f"sweep_llc_{a}_{c.label()}", us_each, f"speedup={s:.2f}")
            for (a, c), s in zip(pairs, vals)]


def sweep_mshr():
    """MSHR saturation: mshrs=1 serializes indexed-pattern (gather) misses —
    canneal degrades, the unit-stride apps stay within noise."""
    from repro.core import engine as eng
    from repro.core import suite
    apps = ("canneal", "blackscholes", "jacobi-2d")
    pairs = [(a, eng.VectorEngineConfig(mvl=64, lanes=4, mshrs=m))
             for a in apps for m in (1, 4, 16)]
    t0 = time.perf_counter()
    vals = suite.speedup_batch(pairs)
    us_each = (time.perf_counter() - t0) * 1e6 / len(pairs)
    return [(f"sweep_mshr_{a}_{c.label()}", us_each, f"speedup={s:.2f}")
            for (a, c), s in zip(pairs, vals)]


def sweep_wallclock(quick: bool = False):
    """The acceptance benchmark: the full 24-config x 10-app sweep (7 RiVec
    + 3 frontend-derived ML workloads), batched engine vs the sequential
    per-(app, config) seed path."""
    from repro.core import engine as eng
    from repro.core import suite
    from repro.core import tracegen
    if quick:
        apps, mvls, lanes = ["blackscholes", "ssd_scan"], (8, 64), (1, 8)
    else:
        apps, mvls, lanes = sorted(tracegen.APPS), (8, 16, 32, 64, 128, 256), (1, 2, 4, 8)
    n = len(apps) * len(mvls) * len(lanes)
    t0 = time.perf_counter()
    batched = suite.sweep_all(apps, mvls=mvls, lanes=lanes)
    t_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    seq = {a: {(m, l): suite.speedup(a, eng.VectorEngineConfig(mvl=m, lanes=l))
               for m in mvls for l in lanes} for a in apps}
    t_seq = time.perf_counter() - t0
    worst = max(abs(batched[a][k] - seq[a][k]) / seq[a][k]
                for a in apps for k in seq[a])
    label = "quick" if quick else "full"
    _BENCH["sweep"] = {
        "mode": label, "n_cells": n, "apps": list(apps),
        "wall_s_batched": t_batched, "wall_s_sequential": t_seq,
        "batched_speedup": t_seq / t_batched, "max_rel_diff": worst,
        "jit_cache": eng.jit_cache_size(),
    }
    return [
        (f"sweep_{label}_{n}cfg_batched", t_batched * 1e6,
         f"wall_s={t_batched:.2f}"),
        (f"sweep_{label}_{n}cfg_sequential", t_seq * 1e6,
         f"wall_s={t_seq:.2f}"),
        (f"sweep_{label}_batched_speedup", 0.0,
         f"{t_seq / t_batched:.1f}x|max_rel_diff={worst:.2e}"
         f"|jit_cache={eng.jit_cache_size()}"),
    ]


def steady_state_table():
    """Per-app steady-state loop-body times at the reference config — the
    per-app entry of the bench JSON, one batched dispatch set.  PR 10 adds
    the marginal lane/VMU utilization over the measurement window."""
    from repro.core import engine as eng
    from repro.core import suite, tracegen
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    apps = sorted(tracegen.APPS)
    bodies = [tracegen.body_for(a, suite.effective_mvl(a, cfg), cfg)
              for a in apps]
    t0 = time.perf_counter()
    rows = eng.steady_state_time_batch(bodies, [cfg] * len(apps),
                                       with_util=True)
    us_each = (time.perf_counter() - t0) * 1e6 / len(apps)
    _BENCH["steady_state_ns"] = {a: r["steady_ns"]
                                 for a, r in zip(apps, rows)}
    _BENCH["steady_state_util"] = {
        a: {"lane_util": r["lane_util"], "vmu_util": r["vmu_util"]}
        for a, r in zip(apps, rows)}
    _BENCH["steady_state_config"] = cfg.label()
    return [(f"steady_state_{a}_{cfg.label()}", us_each,
             f"{r['steady_ns']:.1f}ns|lane_util={r['lane_util']:.3f}"
             f"|vmu_util={r['vmu_util']:.3f}")
            for a, r in zip(apps, rows)]


def profile_rows(quick: bool = False, timeline_path: str | None = None):
    """Mechanistic cycle-attribution rows (ISSUE 10): the per-app telemetry
    scorecard at the reference config (plus the ooo/crossbar corner in full
    mode) and a committed example Chrome-trace timeline.

    Each row prints the top bottleneck module, the module fractions, and the
    event-sum identity error (attributed cycles must reconstruct the total
    runtime to float32 tolerance)."""
    from repro.core import engine as eng
    from repro.core import suite, telemetry, tracegen
    cfgs = [eng.VectorEngineConfig(mvl=64, lanes=4)]
    if not quick:
        cfgs.append(eng.VectorEngineConfig(mvl=256, lanes=8, ooo_issue=True,
                                           interconnect="crossbar"))
    t0 = time.perf_counter()
    rep = telemetry.scorecard(cfgs=cfgs)
    wall = time.perf_counter() - t0
    us_each = wall * 1e6 / len(rep.rows)
    worst_ident = max(r["identity_rel_err"] for r in rep.rows)
    rows = []
    for r in rep.rows:
        fracs = "|".join(f"{m}={r['modules'][m]:.3f}"
                         for m in telemetry.MODULES)
        rows.append((f"profile_{r['app']}_{r['config']}", us_each,
                     f"top={r['top']}|{fracs}"
                     f"|ident_err={r['identity_rel_err']:.1e}"))
    if timeline_path is None:
        timeline_path = os.path.join(os.path.dirname(__file__), "..",
                                     "examples",
                                     "timeline_blackscholes.json")
    os.makedirs(os.path.dirname(timeline_path), exist_ok=True)
    app, cfg = "blackscholes", cfgs[0]
    body = tracegen.body_for(app, suite.effective_mvl(app, cfg), cfg)
    doc = telemetry.write_chrome_trace(timeline_path, body.tile(2), cfg,
                                       label=app)
    rows.append(("profile_timeline_blackscholes", 0.0,
                 f"{len(doc['traceEvents'])}events"
                 f"|{os.path.normpath(timeline_path)}"))
    _BENCH["profile"] = {
        "scorecard": rep.to_dict(), "wall_s": wall,
        "worst_identity_rel_err": worst_ident,
        "timeline": os.path.normpath(timeline_path),
        "jit_cache": eng.jit_cache_size(),
    }
    return rows


def frontend_crossval():
    """Jaxpr-frontend cross-validation (derived vs hand-coded bodies): the
    static mixes must match exactly, steady-state time within 5%."""
    from repro.core import frontend as fe
    t0 = time.perf_counter()
    reports = fe.cross_validate_all()
    us_each = (time.perf_counter() - t0) * 1e6 / len(reports)
    _BENCH["frontend_crossval"] = {
        "all_ok": all(r.ok for r in reports),
        "worst_time_rel_err": max(r.time_rel_err for r in reports),
        "apps": sorted({r.app for r in reports}),
    }
    return [(f"frontend_crossval_{r.app}", us_each,
             f"time_err={r.time_rel_err:.4f}|{'ok' if r.ok else 'FAIL'}")
            for r in reports]


def rvv_rows(quick: bool = False):
    """RVV assembly frontend rows: per-app decode wall-clock (corpus ->
    isa.Trace through the abstract interpreter), asm-vs-hand cross-validation
    verdicts, and asm-variant sweep parity against the hand-coded suite.

    ``--quick`` cross-validates at the two PR-3 reference configs; the full
    run uses the per-MVL grid the ci.sh ``rvv-crossval`` gate enforces."""
    from repro.core import engine as eng
    from repro.core import rvv, suite, tracegen
    rows = []
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    corpus = [a for a in sorted(tracegen.APPS) if tracegen.APPS[a].asm]
    rvv._DECODE_CACHE.clear()
    t0 = time.perf_counter()
    for app in corpus:
        ta = time.perf_counter()
        d = rvv.decode_app(app, suite.effective_mvl(app, cfg), cfg)
        us = (time.perf_counter() - ta) * 1e6
        rows.append((f"rvv_decode_{app}", us,
                     f"{len(d.trace)}entries|chunks={d.chunks:g}"))
    decode_wall = time.perf_counter() - t0
    cfgs = [cfg, eng.VectorEngineConfig(mvl=16, lanes=2)] if quick else None
    t0 = time.perf_counter()
    reports = rvv.cross_validate_all(cfgs=cfgs)
    crossval_wall = time.perf_counter() - t0
    worst = max(r.time_rel_err for r in reports)
    n_bitwise = sum(r.fingerprint_eq for r in reports)
    for r in reports:
        rows.append((f"rvv_crossval_{r.app}_{r.cfg_label}", 0.0,
                     f"time_err={r.time_rel_err:.4f}"
                     f"|{'bitwise' if r.fingerprint_eq else 'mix-exact'}"
                     f"|{'ok' if r.ok else 'FAIL'}"))
    # asm-variant sweep parity: the :asm suite through the batched engine
    t0 = time.perf_counter()
    asm_tab = suite.sweep_all(tracegen.ASM_APPS, mvls=(8, 64, 256),
                              lanes=(1, 8))
    hand_tab = suite.sweep_all(corpus, mvls=(8, 64, 256), lanes=(1, 8))
    sweep_wall = time.perf_counter() - t0
    worst_sweep = max(
        abs(asm_tab[f"{a}:asm"][k] - hand_tab[a][k]) / hand_tab[a][k]
        for a in corpus for k in hand_tab[a])
    rows.append(("rvv_asm_sweep_parity", sweep_wall * 1e6,
                 f"max_rel_diff={worst_sweep:.2e}|cells="
                 f"{sum(len(v) for v in asm_tab.values())}"))
    _BENCH["rvv"] = {
        "decode_wall_s": decode_wall,
        "crossval_wall_s": crossval_wall,
        "all_ok": all(r.ok for r in reports),
        "worst_time_rel_err": worst,
        "n_reports": len(reports),
        "n_bitwise_identical": n_bitwise,
        "asm_sweep_max_rel_diff": worst_sweep,
    }
    return rows


def codegen_rows(quick: bool = False):
    """RVV codegen rows: per-app emit wall-clock (jaxpr kernel spec ->
    generated assembly) and emit->decode round-trip verdicts vs the direct
    lowering (bitwise fingerprints + exact chunk counts).

    ``--quick`` round-trips at the grid extremes {8, 256}; the full run
    uses every MVL the ci.sh ``codegen-roundtrip`` gate enforces."""
    from repro.core import codegen, crossval, tracegen
    rows = []
    apps = [a for a in sorted(tracegen.APPS)
            if tracegen.APPS[a].kernel is not None]
    texts = {}
    for app in apps:
        t0 = time.perf_counter()
        texts[app] = codegen.emit_app(app)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"codegen_emit_{app}", us,
                     f"{len(texts[app].splitlines())}lines"))
    mvls = (8, 256) if quick else None
    t0 = time.perf_counter()
    reports = []
    for app in apps:
        reports += crossval.round_trip_app(app, text=texts[app], mvls=mvls)
    wall = time.perf_counter() - t0
    for r in reports:
        rows.append((f"codegen_roundtrip_{r.app}_mvl{r.mvl}", 0.0,
                     f"{'bitwise' if r.fingerprint_eq else 'DIVERGED'}"
                     f"|{'ok' if r.ok else 'FAIL'}"))
    _BENCH["codegen"] = {
        "roundtrip_wall_s": wall,
        "all_ok": all(r.ok for r in reports),
        "n_reports": len(reports),
        "n_bitwise": sum(r.fingerprint_eq for r in reports),
        "emitted_lines": {a: len(t.splitlines()) for a, t in texts.items()},
    }
    return rows


def dse_study(quick: bool = False, cache_path: str | None = None,
              budget_kb: float = 512.0):
    """Design-space exploration acceptance rows: enumerate a DSE space
    (quick: the 384-point ``SPACE_QUICK``; full: the 1536-point
    ``SPACE_FULL`` over all 10 apps), shard the config axis across local
    devices, dedup dispatches through the persistent result cache, and
    reduce to per-app Pareto frontiers + best-config-under-budget.

    A repeated invocation with the same ``--dse-cache`` must report >=99%
    cache hits and an identical ``frontier_fingerprint`` in the bench JSON
    (the DSE determinism contract)."""
    from repro.configs import vector_engine as vcfg
    from repro.core import dse
    space = vcfg.SPACE_QUICK if quick else vcfg.SPACE_FULL
    apps = vcfg.SPACE_PRESET_APPS["quick" if quick else "full"]
    cache = dse.ResultCache(cache_path)
    t0 = time.perf_counter()
    res = dse.explore(space, apps, cache=cache)
    wall = time.perf_counter() - t0
    frontiers = res.frontiers()
    fp = dse._frontier_fingerprint(res)
    _BENCH["dse"] = {
        "space": res.space, "n_configs": res.n_configs,
        "apps": list(res.apps), "n_cells": len(res.records),
        "wall_s": wall, "cache": res.stats, "cache_path": cache_path,
        "frontier_fingerprint": fp,
        "frontiers": dse.frontier_summary(res, budgets=(256.0, budget_kb,
                                                        1024.0)),
    }
    rows = [(f"dse_{res.space}_{res.n_configs}cfg_{len(res.apps)}apps",
             wall * 1e6,
             f"wall_s={wall:.2f}|simulated={res.stats['simulated']}"
             f"|hit_rate={res.stats['hit_rate']:.3f}"
             f"|devices={res.stats['devices']}|frontier_fp={fp}")]
    by_app = res.by_app()
    for app in res.apps:
        best = dse.best_under_budget(by_app[app], budget_kb)
        rows.append((f"dse_frontier_{app}", 0.0,
                     f"{len(frontiers[app])}pts|best{budget_kb:g}kb="
                     f"{best.label if best else 'none'}"))
    return rows


def surrogate_rows(quick: bool = False, cache_path: str | None = None,
                   seed: int = 0):
    """Surrogate-guided search acceptance rows (ISSUE 8).

    Full mode: exhaustively explore the 1536-point ``SPACE_FULL`` over all
    10 apps (the truth frontiers AND the ~15k training rows), fit the MLP
    surrogate, then surrogate-search the 1,244,160-point ``SPACE_HUGE`` and
    measure (a) wall-clock vs the exact explore, (b) surrogate scoring
    throughput vs exact simulation throughput, and (c) recall of each
    exact-verified search frontier against the exhaustive truth frontier
    (acceptance: >= 0.9).  A second model trained WITHOUT the last app
    provides the honest held-out-app error CDF.  Quick mode: the same
    pipeline on SPACE_QUICK -> SPACE_10K with 3 apps.
    """
    from repro.configs import vector_engine as vcfg
    from repro.core import dse, surrogate, search, tracegen
    if quick:
        truth_space, search_space = vcfg.SPACE_QUICK, vcfg.SPACE_10K
        apps = vcfg.SPACE_PRESET_APPS["quick"]
        steps = 800
    else:
        truth_space, search_space = vcfg.SPACE_FULL, vcfg.SPACE_HUGE
        apps = tuple(sorted(tracegen.APPS))
        steps = 2000
    cache = dse.ResultCache(cache_path)

    t0 = time.perf_counter()
    truth = dse.explore(truth_space, apps, cache=cache)
    t_exact = time.perf_counter() - t0
    rows_lab = cache.export_training_rows(apps, truth_space)

    t0 = time.perf_counter()
    model = surrogate.fit(rows_lab, steps=steps, seed=seed)
    t_fit = time.perf_counter() - t0
    fit_card = surrogate.scorecard(model, rows_lab)

    # honest generalization: a second model that never saw the last app
    holdout = apps[-1]
    t0 = time.perf_counter()
    ho_model = surrogate.fit([r for r in rows_lab if r["app"] != holdout],
                             steps=steps, seed=seed)
    t_fit_ho = time.perf_counter() - t0
    ho_rows = [r for r in rows_lab if r["app"] == holdout]
    # the error CDF over ONLY the never-seen app's cells — the honest
    # unseen-workload generalization number
    ho_card = surrogate.scorecard(ho_model, ho_rows, holdout_app=holdout)

    # pure scoring throughput: one app across the whole search space
    scorer = surrogate.SpaceScorer(model, search_space, apps[0])
    idx = np.arange(search_space.size(), dtype=np.int64)
    scorer.score(idx[: surrogate.SCORE_BATCH])          # compile
    t0 = time.perf_counter()
    scorer.score(idx)
    t_score = time.perf_counter() - t0
    score_pts_s = search_space.size() / t_score
    exact_cells_s = len(truth.records) / t_exact

    t0 = time.perf_counter()
    res = search.search(search_space, apps, model, cache=cache, seed=seed)
    t_search = time.perf_counter() - t0
    n_checked = search._verify_exact(res, cache)

    tf = truth.frontiers()
    recall = {a: search.frontier_recall(res.frontiers[a], tf[a])
              for a in apps}
    rmean = float(np.mean(list(recall.values())))
    rmin = min(recall.values())
    t_pipeline = t_fit + t_search
    _BENCH["surrogate"] = {
        "truth_space": truth_space.name,
        "search_space": search_space.name,
        "search_space_size": search_space.size(),
        "apps": list(apps),
        "n_training_rows": len(rows_lab),
        "exact_wall_s": t_exact,
        "train_s": t_fit,
        "train_holdout_s": t_fit_ho,
        "search_wall_s": t_search,
        "pipeline_wall_s": t_pipeline,
        "score_throughput_pts_s": score_pts_s,
        "exact_throughput_cells_s": exact_cells_s,
        "recall_at_frontier": recall,
        "recall_mean": rmean,
        "recall_min": rmin,
        "frontier_points_exact_verified": n_checked,
        "frontier_fingerprint": search.frontier_fingerprint(res),
        "search_stats": res.stats,
        "fit_error_cdf": {k: fit_card[k] for k in
                          ("rel_err_p50", "rel_err_p90", "rel_err_p99",
                           "rel_err_max", "spearman_all")},
        "holdout_app": holdout,
        "holdout_error_cdf": {k: ho_card[k] for k in
                              ("rel_err_p50", "rel_err_p90", "rel_err_p99",
                               "rel_err_max", "spearman_all")},
    }
    rows = [
        (f"surrogate_train_{len(rows_lab)}rows", t_fit * 1e6,
         f"steps={steps}|final_loss={model.meta['final_loss']:.2e}"
         f"|p50={fit_card['rel_err_p50']:.4f}"
         f"|p90={fit_card['rel_err_p90']:.4f}"),
        (f"surrogate_score_{search_space.name}", t_score * 1e6,
         f"{score_pts_s:,.0f}pts/s_vs_exact_{exact_cells_s:.0f}cells/s"
         f"|x{score_pts_s / exact_cells_s:,.0f}"),
        (f"surrogate_search_{search_space.name}_{search_space.size()}cfg",
         t_search * 1e6,
         f"pipeline_s={t_pipeline:.1f}|exact_s={t_exact:.1f}"
         f"|scored={res.stats['n_scored']}|verified={n_checked}"),
        (f"surrogate_recall_{truth_space.name}_truth", 0.0,
         f"mean={rmean:.3f}|min={rmin:.3f}"
         f"|holdout_{holdout}_p50={ho_card['rel_err_p50']:.4f}"
         f"|holdout_spearman={ho_card['spearman_all']:.4f}"),
    ]
    return rows


def serve_rows(quick: bool = False, cache_path: str | None = None,
               seed: int = 0):
    """Simulation-service acceptance rows: sustained throughput and p50/p99
    latency under a (seeded) Poisson arrival workload with zero steady-state
    recompiles; the repeated identical stream must answer >= 99 % of
    requests from the ResultCache with bitwise-identical times."""
    try:
        from benchmarks import serve_bench
    except ImportError:
        import serve_bench
    rows, bench = serve_bench.serve_study(quick=quick, cache_path=cache_path,
                                          seed=seed)
    _BENCH["serve"] = bench
    return rows


def kernel_microbench():
    from repro.kernels import ops
    k = jax.random.key
    rows = []
    n = 16384
    args = (jax.random.uniform(k(0), (n,), jnp.float32, 10, 100),
            jax.random.uniform(k(1), (n,), jnp.float32, 10, 100),
            jnp.full((n,), 0.05),
            jax.random.uniform(k(2), (n,), jnp.float32, 0.1, 0.6),
            jax.random.uniform(k(3), (n,), jnp.float32, 0.2, 2.0),
            (jax.random.uniform(k(4), (n,)) > 0.5).astype(jnp.int32))
    us = _t(lambda *a: ops.blackscholes(*a), *args)
    rows.append(("kernel_blackscholes", us, f"{n/us:.1f}Mopt_s"))
    a = jax.random.normal(k(5), (258, 512))
    us = _t(lambda x: ops.jacobi2d_step(x, rows_per_block=64), a)
    rows.append(("kernel_jacobi2d", us, f"{a.size/us:.0f}Melem_s"))
    wall = jax.random.uniform(k(6), (64, 512))
    us = _t(ops.pathfinder, wall)
    rows.append(("kernel_pathfinder", us, ""))
    p = jax.random.normal(k(7), (1024, 128))
    c = jax.random.normal(k(8), (512, 128))
    us = _t(ops.streamcluster_dist, p, c)
    gf = 2 * p.shape[0] * c.shape[0] * 128 / us / 1e3
    rows.append(("kernel_streamcluster_dist", us, f"{gf:.2f}GFLOP_s"))
    u = jax.random.uniform(k(9), (n,), minval=1e-5, maxval=1 - 1e-5)
    us = _t(ops.cum_normal_inv, u)
    rows.append(("kernel_swaptions_cni", us, ""))
    locs = jax.random.randint(k(10), (1024, 2), 0, 1000).astype(jnp.float32)
    fan = jax.random.randint(k(11), (512, 24), -1, 1024)
    ca = jax.random.randint(k(12), (512, 2), 0, 1000).astype(jnp.float32)
    us = _t(lambda *a: ops.canneal_swap_cost(*a), locs, fan, ca, ca)
    rows.append(("kernel_canneal_swapcost", us, ""))
    cdf = jnp.sort(jax.random.uniform(k(13), (8192,)))
    uq = jax.random.uniform(k(14), (1024,))
    us = _t(ops.particlefilter_findindex, cdf, uq)
    rows.append(("kernel_pf_findindex", us, ""))
    q = jax.random.normal(k(15), (1, 512, 4, 64), jnp.float32)
    us = _t(lambda q: ops.flash_attention(q, q, q, bq=128, bk=128), q)
    rows.append(("kernel_flash_attention", us, ""))
    x = jax.random.normal(k(16), (1, 512, 4, 16))
    dt = jax.nn.softplus(jax.random.normal(k(17), (1, 512, 4)))
    A = -jnp.exp(jax.random.normal(k(18), (4,)) * 0.3)
    Bm = jax.random.normal(k(19), (1, 512, 32))
    us = _t(lambda *a: ops.ssd_scan(*a, chunk=128), x, dt, A, Bm, Bm)
    rows.append(("kernel_ssd_scan", us, ""))
    return rows


def roofline_table():
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.jsonl")
    if not os.path.exists(path):
        return [("roofline", 0.0, "results/dryrun.jsonl missing")]
    rows = {}
    for line in open(path):
        r = json.loads(line)
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    out = []
    for (arch, shape, mesh), r in sorted(rows.items()):
        if mesh != "16x16":
            continue
        rl = r["roofline"]
        tmax = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        out.append((f"roofline_{arch}_{shape}", 0.0,
                    f"bound={rl['bound']}|t={tmax:.3f}s|frac={rl['roofline_fraction']:.3f}"))
    return out


# Pre-PR-9 scalar baselines (ns): the retired SCALAR_BASELINE_MULT model's
# per-app runtimes, frozen so `--scalar` can report old-vs-new drift across
# the event-model replacement.
_OLD_SCALAR_NS = {
    "blackscholes": 7.857e9, "canneal": 6.160e9, "jacobi-2d": 7.835e9,
    "particlefilter": 2.172e9, "pathfinder": 7.115e9,
    "streamcluster": 3.999e10, "swaptions": 2.669e10,
    "flash_attention": 3.042e10, "decode_attention": 1.785e9,
    "ssd_scan": 2.475e8,
}


def scalar_rows():
    """Scalar-baseline rows: per-app old-vs-new runtime, the 11-anchor
    rel-err table, and the scorecard wall-clock."""
    from repro.core import engine as eng
    from repro.core import scalar_pipeline as sp
    from repro.core import suite, tracegen
    from repro.core.anchors import ANCHORS

    rows = []
    bench = _BENCH.setdefault("scalar", {})
    for app in sorted(_OLD_SCALAR_NS):
        t0 = time.perf_counter()
        new = sp.scalar_runtime_ns(app)
        us = (time.perf_counter() - t0) * 1e6
        old = _OLD_SCALAR_NS[app]
        prof = tracegen.scalar_profile_for(app)
        n = tracegen.app_for(app).counts(8).scalar_code_total \
            * prof.roi_instr_fraction
        cpi = sp.scalar_cycles(app) / n
        rows.append((f"scalar_baseline_{app}", us,
                     f"old={old:.4g}ns|new={new:.4g}ns|"
                     f"ratio={new / old:.4f}|cpi={cpi:.3f}"))
        bench[app] = {"old_ns": old, "new_ns": new, "cpi": cpi}

    t0 = time.perf_counter()
    anchor_rows = []
    for app, mvl, lanes, target, kind in ANCHORS:
        cfg = eng.VectorEngineConfig(mvl=mvl, lanes=lanes)
        got = suite.speedup(app, cfg)
        anchor_rows.append((f"scalar_anchor_{app}_mvl{mvl}_l{lanes}", 0.0,
                            f"model={got:.3f}|paper={target:.3f}|"
                            f"rel_err={got / target - 1.0:+.3f}|{kind}"))
        bench.setdefault("anchors", {})[f"{app}@{mvl}x{lanes}"] = {
            "model": got, "paper": target, "kind": kind}
    wall = time.perf_counter() - t0
    rows += anchor_rows
    rows.append(("scalar_scorecard_wallclock", wall * 1e6,
                 f"{len(anchor_rows)}_anchors"))
    bench["scorecard_wallclock_s"] = wall
    return rows


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: characterization + batched figures + "
                         "frontend cross-validation + a small batched-vs-"
                         "sequential sweep; skips kernel microbenchmarks and "
                         "the roofline table.  With --dse: the 384-point "
                         "SPACE_QUICK instead of the 1536-point SPACE_FULL")
    ap.add_argument("--dse", action="store_true",
                    help="design-space exploration rows only: enumerate the "
                         "DSE space, shard across devices, dedup through "
                         "--dse-cache, report Pareto frontiers + cache-hit "
                         "stats (a repeat run must be >=99%% hits with an "
                         "identical frontier fingerprint)")
    ap.add_argument("--scalar", action="store_true",
                    help="scalar-baseline rows only: per-app old-vs-new "
                         "runtime across the event-model replacement, the "
                         "11-anchor rel-err table, scorecard wall-clock")
    ap.add_argument("--rvv", action="store_true",
                    help="RVV assembly frontend rows only: per-app decode "
                         "wall-clock, asm-vs-hand cross-validation "
                         "verdicts, and asm-variant sweep parity")
    ap.add_argument("--profile", action="store_true",
                    help="mechanistic cycle-attribution rows only: the "
                         "telemetry scorecard (top bottleneck + module "
                         "fractions + event-sum identity error per app) and "
                         "the committed example Chrome-trace timeline "
                         "(examples/timeline_blackscholes.json)")
    ap.add_argument("--serve", action="store_true",
                    help="simulation-service rows only: Poisson arrival "
                         "workload through repro.serve.sim_service — "
                         "sustained throughput, p50/p99 latency, zero "
                         "steady-state recompiles; the repeat pass must be "
                         ">=99%% ResultCache hits, bitwise-identical")
    ap.add_argument("--surrogate", action="store_true",
                    help="surrogate-guided search rows only: exhaustive "
                         "truth explore (SPACE_QUICK/--quick or SPACE_FULL), "
                         "train the MLP cost model on the mined cache rows, "
                         "search SPACE_10K/SPACE_HUGE, report train "
                         "wall-clock, scoring throughput, recall@frontier "
                         "vs exhaustive truth, and the held-out-app error "
                         "CDF")
    ap.add_argument("--dse-cache", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "dse_cache.jsonl"),
        help="persistent DSE result cache (JSONL)")
    ap.add_argument("--surrogate-cache", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "surrogate_cache.jsonl"),
        help="persistent result cache for the surrogate truth explore + "
             "exact re-simulation (JSONL)")
    ap.add_argument("--serve-cache", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "serve_cache.jsonl"),
        help="persistent simulation-service result cache (JSONL)")
    ap.add_argument("--dse-budget-kb", type=float, default=512.0)
    ap.add_argument("--bench-json", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_pr10.json"),
        help="machine-readable results path (sweep wall-clock, batched "
             "speedup, per-app steady-state times + lane/VMU utilization, "
             "crossval verdicts incl. the RVV frontend, DSE frontiers + "
             "cache stats, serving throughput/latency, surrogate "
             "train/score/recall, scalar-baseline old-vs-new + anchor "
             "scorecard, mechanistic profile scorecard)")
    args = ap.parse_args(argv)
    if args.surrogate:
        fns = (lambda: surrogate_rows(quick=args.quick,
                                      cache_path=args.surrogate_cache),)
    elif args.dse:
        fns = (lambda: dse_study(quick=args.quick,
                                 cache_path=args.dse_cache,
                                 budget_kb=args.dse_budget_kb),)
    elif args.profile:
        fns = (lambda: profile_rows(quick=args.quick),)
    elif args.serve:
        fns = (lambda: serve_rows(quick=args.quick,
                                  cache_path=args.serve_cache),)
    elif args.rvv:
        fns = (lambda: rvv_rows(quick=args.quick),)
    elif args.scalar:
        fns = (scalar_rows,)
    elif args.quick:
        fns = (table_3_to_9_characterization, figures_4_to_10_scalability,
               sweep_llc, sweep_mshr, frontend_crossval,
               lambda: rvv_rows(quick=True),
               lambda: codegen_rows(quick=True), steady_state_table,
               scalar_rows, lambda: profile_rows(quick=True),
               lambda: sweep_wallclock(quick=True))
    else:
        fns = (table_3_to_9_characterization, figures_4_to_10_scalability,
               sweep_llc, sweep_mshr, frontend_crossval,
               lambda: rvv_rows(), lambda: codegen_rows(),
               steady_state_table, scalar_rows, lambda: profile_rows(),
               kernel_microbench, roofline_table,
               lambda: sweep_wallclock(quick=False))
    print("name,us_per_call,derived")
    for fn in fns:
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")
    # Merge into an existing snapshot so single-mode runs (--scalar,
    # --surrogate, ...) layer their sections instead of clobbering the rest.
    merged = {}
    if os.path.exists(args.bench_json):
        try:
            with open(args.bench_json) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(_BENCH)
    with open(args.bench_json, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.normpath(args.bench_json)}")


if __name__ == "__main__":
    main()
