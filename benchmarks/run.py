"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  For the characterization tables
(3-9) `derived` is the max relative error vs the published cells; for the
scalability figures (4-10) it is the modeled speedup; for kernels it is
throughput; for the roofline it is the dominant term + roofline fraction.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _t(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def table_3_to_9_characterization():
    from repro.core import characterize as ch
    rows = []
    for app in ch.PAPER_TABLES:
        t0 = time.perf_counter()
        errs = ch.compare_to_paper(app)
        us = (time.perf_counter() - t0) * 1e6
        worst = max(v for r in errs for k, v in r.items() if k.startswith("err"))
        rows.append((f"table_characterization_{app}", us, f"max_err={worst:.4f}"))
        vao = ch.characterize(app, 8).vao_speedup
        rows.append((f"vao_speedup_{app}", 0.0, f"{vao:.3f}"))
    return rows


def figures_4_to_10_scalability():
    from repro.core import engine as eng
    from repro.core import suite
    rows = []
    for app in ("blackscholes", "canneal", "jacobi-2d", "particlefilter",
                "pathfinder", "streamcluster", "swaptions"):
        for mvl in (8, 64, 256):
            for lanes in (1, 8):
                cfg = eng.VectorEngineConfig(mvl=mvl, lanes=lanes)
                t0 = time.perf_counter()
                s = suite.speedup(app, cfg)
                us = (time.perf_counter() - t0) * 1e6
                rows.append((f"fig_scalability_{app}_mvl{mvl}_l{lanes}", us,
                             f"speedup={s:.2f}"))
    # Fig 10: swaptions LLC study
    for l2 in (256, 1024):
        cfg = eng.VectorEngineConfig(mvl=256, lanes=8, l2_kb=l2)
        s = suite.speedup("swaptions", cfg)
        rows.append((f"fig10_swaptions_l2_{l2}kb", 0.0, f"speedup={s:.2f}"))
    return rows


def kernel_microbench():
    from repro.kernels import ops
    k = jax.random.key
    rows = []
    n = 16384
    args = (jax.random.uniform(k(0), (n,), jnp.float32, 10, 100),
            jax.random.uniform(k(1), (n,), jnp.float32, 10, 100),
            jnp.full((n,), 0.05),
            jax.random.uniform(k(2), (n,), jnp.float32, 0.1, 0.6),
            jax.random.uniform(k(3), (n,), jnp.float32, 0.2, 2.0),
            (jax.random.uniform(k(4), (n,)) > 0.5).astype(jnp.int32))
    us = _t(lambda *a: ops.blackscholes(*a), *args)
    rows.append(("kernel_blackscholes", us, f"{n/us:.1f}Mopt_s"))
    a = jax.random.normal(k(5), (258, 512))
    us = _t(lambda x: ops.jacobi2d_step(x, rows_per_block=64), a)
    rows.append(("kernel_jacobi2d", us, f"{a.size/us:.0f}Melem_s"))
    wall = jax.random.uniform(k(6), (64, 512))
    us = _t(ops.pathfinder, wall)
    rows.append(("kernel_pathfinder", us, ""))
    p = jax.random.normal(k(7), (1024, 128))
    c = jax.random.normal(k(8), (512, 128))
    us = _t(ops.streamcluster_dist, p, c)
    gf = 2 * p.shape[0] * c.shape[0] * 128 / us / 1e3
    rows.append(("kernel_streamcluster_dist", us, f"{gf:.2f}GFLOP_s"))
    u = jax.random.uniform(k(9), (n,), minval=1e-5, maxval=1 - 1e-5)
    us = _t(ops.cum_normal_inv, u)
    rows.append(("kernel_swaptions_cni", us, ""))
    locs = jax.random.randint(k(10), (1024, 2), 0, 1000).astype(jnp.float32)
    fan = jax.random.randint(k(11), (512, 24), -1, 1024)
    ca = jax.random.randint(k(12), (512, 2), 0, 1000).astype(jnp.float32)
    us = _t(lambda *a: ops.canneal_swap_cost(*a), locs, fan, ca, ca)
    rows.append(("kernel_canneal_swapcost", us, ""))
    cdf = jnp.sort(jax.random.uniform(k(13), (8192,)))
    uq = jax.random.uniform(k(14), (1024,))
    us = _t(ops.particlefilter_findindex, cdf, uq)
    rows.append(("kernel_pf_findindex", us, ""))
    q = jax.random.normal(k(15), (1, 512, 4, 64), jnp.float32)
    us = _t(lambda q: ops.flash_attention(q, q, q, bq=128, bk=128), q)
    rows.append(("kernel_flash_attention", us, ""))
    x = jax.random.normal(k(16), (1, 512, 4, 16))
    dt = jax.nn.softplus(jax.random.normal(k(17), (1, 512, 4)))
    A = -jnp.exp(jax.random.normal(k(18), (4,)) * 0.3)
    Bm = jax.random.normal(k(19), (1, 512, 32))
    us = _t(lambda *a: ops.ssd_scan(*a, chunk=128), x, dt, A, Bm, Bm)
    rows.append(("kernel_ssd_scan", us, ""))
    return rows


def roofline_table():
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.jsonl")
    if not os.path.exists(path):
        return [("roofline", 0.0, "results/dryrun.jsonl missing")]
    rows = {}
    for line in open(path):
        r = json.loads(line)
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    out = []
    for (arch, shape, mesh), r in sorted(rows.items()):
        if mesh != "16x16":
            continue
        rl = r["roofline"]
        tmax = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        out.append((f"roofline_{arch}_{shape}", 0.0,
                    f"bound={rl['bound']}|t={tmax:.3f}s|frac={rl['roofline_fraction']:.3f}"))
    return out


def main() -> None:
    print("name,us_per_call,derived")
    for fn in (table_3_to_9_characterization, figures_4_to_10_scalability,
               kernel_microbench, roofline_table):
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
