"""Calibrate the scalar-pipeline model against the paper's §5 anchors.

The event-based scalar model (``repro.core.scalar_pipeline``) has exactly
ONE fitted parameter per app — ``mem_stall_cyc``, the average scalar-load
stall beyond the pipelined L1 hit — plus particlefilter's explicit
``roi_instr_fraction`` correction.  Everything else (op latencies, issue
width, divider structural rate, profile fractions) is fixed and documented
in docs/calibration.md.

Fit mode (default) solves both closed-form:

  * each "eq"-anchored app's implied scalar-runtime target is the geomean
    over its anchors of ``paper_speedup x modeled_vector_runtime``; cycles
    are linear in ``mem_stall_cyc`` (slope = the load count), so the fit is
    one division, clipped to the physical band [0, 40] cycles;
  * particlefilter publishes only "never beats scalar" bounds, so its
    ``mem_stall_cyc`` is FIXED at 4.0 (gather-bound profile) and the ROI
    correction is solved instead: cycles scale linearly in
    ``roi_instr_fraction``, targeted at speedup = 0.95 x the tightest "lt"
    bound;
  * the frontend-only ML workloads have no paper anchors; their targets are
    the frozen modeled baselines (continuity with the pre-PR-9 numbers,
    documented as modeled-not-paper-calibrated).

Output is the ``ScalarProfile`` table to paste into
``tracegen.SCALAR_PROFILES`` — the fit is a fixed point of the committed
values.

``--scorecard`` prints the accuracy scorecard: all 11 §5 anchors with
per-anchor relative error, the per-app event breakdown, the residual-error
budget, and the scorecard wall-clock.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import engine as eng
from repro.core import scalar_pipeline as sp
from repro.core import suite, tracegen
from repro.core.anchors import ANCHORS, EQ_HI, EQ_LO, LT_SLACK

# particlefilter's gather-bound load stall is fixed, not fitted (its anchors
# are bounds, not targets — they pin the ROI correction instead)
PF_MEM_STALL = 4.0
# target speedup at PF's tightest "lt" bound: just under the bound
PF_LT_MARGIN = 0.95

# Frozen pre-PR-9 modeled scalar baselines for the anchor-less ML workloads
# (ns).  These came from the retired SCALAR_BASELINE_MULT entries that were
# *modeled* (chosen for a plausible best-config band), not paper-fitted;
# refitting against them keeps the ML numbers continuous across the scalar
# model replacement.
ML_TARGET_NS = {
    "flash_attention": 3.0424e10,
    "decode_attention": 1.7848e9,
    "ssd_scan": 2.4750e8,
}

MEM_STALL_LO, MEM_STALL_HI = 0.0, 40.0


def _cycles_split(app: str) -> tuple[float, float, float]:
    """(cycles at mem_stall=0, load count, current roi) — cycles are linear
    in both fitted parameters: ``cyc = roi_scale x (cyc0 + n_load x ms)``
    where the segment counts already include the committed roi."""
    seg = sp.segments_for(app)
    n_load = float(seg[4, 0])
    seg0 = seg.copy()
    seg0[4, 5] = 0.0
    import jax.numpy as jnp
    cyc0, _ = sp._pipeline_jit(jnp.asarray(seg0),
                               tuple(jnp.asarray(p)
                                     for p in sp.cfg_scalar_params(None)))
    roi = tracegen.scalar_profile_for(app).roi_instr_fraction
    return float(cyc0), n_load, roi


def _anchor_targets() -> dict:
    """Per-app implied scalar-runtime targets (ns): geomean over "eq"
    anchors of ``paper_speedup x modeled_vector_runtime``; for apps with
    only "lt" anchors, ``PF_LT_MARGIN x`` the tightest bound."""
    eq, lt = {}, {}
    for app, mvl, lanes, target, kind in ANCHORS:
        cfg = eng.VectorEngineConfig(mvl=mvl, lanes=lanes)
        v = suite.vector_runtime_ns(app, cfg)
        (eq if kind == "eq" else lt).setdefault(app, []).append(target * v)
    out = {a: float(np.exp(np.mean(np.log(ts)))) for a, ts in eq.items()}
    for a, ts in lt.items():
        if a not in out:
            out[a] = PF_LT_MARGIN * min(ts)
    out.update(ML_TARGET_NS)
    return out


def fit() -> dict:
    """Solve every app's fitted parameter closed-form; returns
    ``{app: (mem_stall_cyc, roi_instr_fraction)}``."""
    freq = eng.VectorEngineConfig().scalar_freq_ghz
    fitted = {}
    for app, target_ns in sorted(_anchor_targets().items()):
        target_cyc = target_ns * freq
        cyc0, n_load, roi = _cycles_split(app)
        if app == "particlefilter":
            # mem stall fixed; solve roi (cycles linear in roi):
            # target = (roi/roi_now) x (cyc0 + n_load x PF_MEM_STALL)
            cyc_roi1 = (cyc0 + n_load * PF_MEM_STALL) / roi
            fitted[app] = (PF_MEM_STALL, target_cyc / cyc_roi1)
        else:
            ms = (target_cyc - cyc0) / n_load
            fitted[app] = (float(np.clip(ms, MEM_STALL_LO, MEM_STALL_HI)),
                           1.0)
    return fitted


def print_fit(fitted: dict) -> None:
    print("fitted ScalarProfile parameters (paste into "
          "tracegen.SCALAR_PROFILES):")
    print(f"  {'app':16s} {'mem_stall_cyc':>13s} {'roi_frac':>9s} "
          f"{'committed':>21s}")
    drift = 0.0
    for app, (ms, roi) in sorted(fitted.items()):
        prof = tracegen.scalar_profile_for(app)
        drift = max(drift, abs(ms - prof.mem_stall_cyc),
                    abs(roi - prof.roi_instr_fraction))
        print(f"  {app:16s} {ms:13.2f} {roi:9.4f} "
              f"  ({prof.mem_stall_cyc:6.2f}, {prof.roi_instr_fraction:.4f})")
    print(f"max |fit - committed| = {drift:.3g} "
          f"({'fixed point: committed values reproduce the fit' if drift < 0.05 else 'STALE — update tracegen.SCALAR_PROFILES'})")


def scorecard() -> int:
    """The accuracy scorecard: anchors + rel-err, event breakdown, residual
    budget, wall-clock.  Returns a process exit code."""
    t0 = time.perf_counter()
    rows = []
    for app, mvl, lanes, target, kind in ANCHORS:
        cfg = eng.VectorEngineConfig(mvl=mvl, lanes=lanes)
        rows.append((app, mvl, lanes, target, kind, suite.speedup(app, cfg)))
    wall = time.perf_counter() - t0

    print("== anchor scorecard (11 paper §5 points) ==")
    print(f"  {'app':16s} {'cfg':>9s} {'model':>6s} {'paper':>6s} "
          f"{'rel-err':>8s}  verdict")
    misses = 0
    for app, mvl, lanes, target, kind, got in rows:
        rel = got / target - 1.0
        if kind == "eq":
            ok = EQ_LO <= got / target <= EQ_HI
            verdict = "ok" if ok else "MISS"
        else:
            ok = got <= target * LT_SLACK
            verdict = "ok (bound)" if ok else "MISS"
        misses += not ok
        print(f"  {app:16s} mvl={mvl:3d}x{lanes} {got:6.2f} {target:6.2f} "
              f"{rel:+8.1%}  [{kind}] {verdict}")
    eq_errs = [abs(np.log(got / target))
               for app, _, _, target, kind, got in rows if kind == "eq"]
    print(f"  geomean |log-err| over eq anchors: "
          f"{float(np.exp(np.mean(eq_errs))) - 1.0:.1%}")

    print("\n== per-app event breakdown (cycles per ROI instruction) ==")
    print(f"  {'app':16s} {'issue':>6s} {'raw':>6s} {'struct':>6s} "
          f"{'bmiss':>6s} {'mem':>6s} {'CPI':>6s}")
    for app in sorted(tracegen.APPS):
        ev = sp.scalar_events(app)
        prof = tracegen.scalar_profile_for(app)
        n = tracegen.app_for(app).counts(8).scalar_code_total \
            * prof.roi_instr_fraction
        bmp = eng.VectorEngineConfig().branch_miss_penalty
        parts = (ev["issue"], ev["raw"], ev["struct"], ev["bmiss"] * bmp,
                 ev["mem"])
        print(f"  {app:16s} " + " ".join(f"{p / n:6.3f}" for p in parts)
              + f" {sum(parts) / n:6.3f}")

    print("\n== residual-error budget ==")
    print(f"  eq anchors: model/paper within [{EQ_LO}, {EQ_HI}] — covers "
          "figure read-off error, the fitted mem_stall_cyc's one-knob "
          "coarseness, and vector-side abstraction (no OoO scalar window).")
    print("  lt anchors: hard bounds (paper's qualitative claims), "
          "no tolerance.")
    pf = tracegen.scalar_profile_for("particlefilter")
    print(f"  particlefilter ROI correction: roi_instr_fraction = "
          f"{pf.roi_instr_fraction:.4f} — the named term for the Table-6 "
          "(instruction counts) vs Figure-7 (timed ROI) accounting "
          "difference; replaces the retired 0.104 multiplier "
          f"(implied CPI {sp.scalar_cycles('particlefilter') / (tracegen.app_for('particlefilter').counts(8).scalar_code_total * pf.roi_instr_fraction):.2f}, physical).")
    print("  ML workloads: no paper anchors; baselines are modeled "
          "(frozen pre-PR-9 continuity targets), excluded from the anchor "
          "budget.")
    print(f"\nscorecard wall-clock: {wall:.2f} s ({len(rows)} anchors)")
    return 1 if misses else 0


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scorecard", action="store_true",
                    help="print the anchor scorecard instead of fitting")
    args = ap.parse_args()
    if args.scorecard:
        sys.exit(scorecard())
    print_fit(fit())
