"""Calibrate the vector-engine timing model against the paper's §5 anchors.

Free parameters:
  * global scalar FU-class latencies (effective ns-per-instruction classes)
  * per-app scalar CPI multiplier (the paper measures each app's scalar
    baseline in gem5; we fit the equivalent — documented in EXPERIMENTS.md)

The vector-side microarchitecture constants (pipe depths, element throughput,
start-up reads) stay FIXED at the paper's §3 description; only the scalar
baseline is fitted.  Outputs the constants to paste into core/engine.py /
core/suite.py and the anchor table for EXPERIMENTS.md.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import engine as eng
from repro.core import suite, tracegen

# (app, mvl, lanes, paper_speedup, kind)  kind: "eq" exact anchor, "lt"/"gt"
ANCHORS = [
    ("blackscholes", 8, 1, 2.22, "eq"),
    ("jacobi-2d", 8, 1, 1.79, "eq"),
    ("jacobi-2d", 256, 1, 2.99, "eq"),
    ("canneal", 16, 1, 1.64, "eq"),
    ("canneal", 16, 8, 1.88, "eq"),
    ("canneal", 256, 1, 1.0, "lt"),
    ("particlefilter", 8, 1, 1.0, "lt"),
    ("particlefilter", 256, 8, 1.0, "lt"),
    ("pathfinder", 8, 1, 1.8, "eq"),
    ("streamcluster", 8, 1, 1.68, "eq"),
    ("swaptions", 8, 1, 1.03, "eq"),
]


def speedups(scalar_mult):
    # fit from scratch: neutralize the baked-in multipliers
    suite.SCALAR_BASELINE_MULT = {a: 1.0 for a in tracegen.APPS}
    out = []
    for app, mvl, lanes, target, kind in ANCHORS:
        cfg = eng.VectorEngineConfig(mvl=mvl, lanes=lanes)
        s = suite.scalar_runtime_ns(app) * scalar_mult.get(app, 1.0)
        v = suite.vector_runtime_ns(app, cfg)
        out.append((app, mvl, lanes, target, kind, s / v))
    return out


def loss(rows):
    total = 0.0
    for app, mvl, lanes, target, kind, got in rows:
        if kind == "eq":
            total += (np.log(got) - np.log(target)) ** 2
        elif kind == "lt" and got > target:
            total += (np.log(got) - np.log(target)) ** 2
    return total


def fit():
    mult = {a: 1.0 for a in tracegen.APPS}
    # per-app multiplier has a closed-form optimum for "eq" anchors sharing
    # the app: geometric mean of target/got.
    for it in range(8):
        rows = speedups(mult)
        by_app = {}
        for app, mvl, lanes, target, kind, got in rows:
            if kind == "eq":
                by_app.setdefault(app, []).append(target / got)
            elif kind == "lt" and got > target:
                by_app.setdefault(app, []).append(target / got * 0.9)
        for app, ratios in by_app.items():
            mult[app] *= float(np.exp(np.mean(np.log(ratios))))
        rows = speedups(mult)
        print(f"iter {it}: loss={loss(rows):.4f}")
        if loss(rows) < 1e-3:
            break
    return mult, speedups(mult)


if __name__ == "__main__":
    mult, rows = fit()
    print("\nfitted per-app scalar CPI multipliers:")
    for app, m in sorted(mult.items()):
        base = suite.scalar_runtime_ns(app)
        counts = tracegen.APPS[app].counts(8)
        cpi = base * m / counts.scalar_code_total / 0.5  # cycles @2GHz
        print(f"  {app:16s} mult={m:6.3f}  -> effective scalar CPI {cpi:4.2f}")
    print("\nanchor table:")
    for app, mvl, lanes, target, kind, got in rows:
        flag = "ok" if (kind == "eq" and abs(np.log(got / target)) < 0.2) or \
                       (kind == "lt" and got <= target) else "MISS"
        print(f"  {app:16s} mvl={mvl:3d} L={lanes} model={got:5.2f} paper={target:5.2f} [{kind}] {flag}")
