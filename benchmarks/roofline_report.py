"""Render EXPERIMENTS.md tables from results/dryrun.jsonl (+ hillclimb.jsonl)."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path):
    rows = {}
    if not os.path.exists(path):
        return rows
    for line in open(path):
        r = json.loads(line)
        rows[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))] = r
    return rows


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile s | HBM used GiB | fits 16GB | per-dev GFLOPs | ICI GB |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh, tag), r in sorted(rows.items()):
        if tag:
            continue
        pd = r["per_device"]
        out.append(
            f"| {arch} | {shape} | {mesh} | {r['compile_s']:.1f} | "
            f"{fmt_bytes(pd['hbm_used_bytes'])} | "
            f"{'yes' if pd['fits_16GB'] else 'NO*'} | "
            f"{pd['flops']/1e9:.1f} | {pd['ici_bytes']/1e9:.2f} |")
    return "\n".join(out)


def roofline_tbl(rows):
    out = ["| arch | shape | t_compute s | t_memory s | t_collective s | bound | useful (6ND/HLO) | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh, tag), r in sorted(rows.items()):
        if mesh != "16x16" or tag:
            continue
        rl = r["roofline"]
        out.append(
            f"| {arch} | {shape} | {rl['t_compute_s']:.4g} | {rl['t_memory_s']:.4g} | "
            f"{rl['t_collective_s']:.4g} | {rl['bound']} | {rl['useful_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.4f} |")
    return "\n".join(out)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=RESULTS,
                    help="results directory holding dryrun.jsonl")
    args = ap.parse_args(argv)
    rows = load(os.path.join(args.results, "dryrun.jsonl"))
    print("## Dry-run table\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single pod 16x16)\n")
    print(roofline_tbl(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
