"""Validate the paper's Table 2 two independent ways: which engine modules
each app stresses.

**Differential** (the original derivation): static trace shares + knob
ablation — manipulation/indexed instruction shares, lane/VMU busy fractions
from the default engine metrics, and the mshrs=1 slowdown.

**Mechanistic** (PR 10): the ``collect_stats`` cycle attribution
(``repro.core.telemetry``) — per-module fractions of where the cycles
actually went, per app, plus the same profile at mshrs=1.

The consistency gate cross-checks them for all 10 apps; any mismatch is a
loud CI failure with the per-module breakdown printed:

  * ``exec_interconnect`` visible cycles > 0  <=>  manip_share > 0
  * ``dep_scalar`` coupling cycles > 0        <=>  app in scalar_comm
  * mshr_bound apps: memory fraction jumps > 0.3 under mshrs=1 and memory
    becomes the top bottleneck; every other app moves < 0.02
  * mechanistic top bottleneck is allowed by the differential busy
    fractions (lanes/memory dominance at the same config)

    PYTHONPATH=src python benchmarks/module_stress.py
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import engine as eng
from repro.core import isa, telemetry, tracegen

# paper Table 2 rows we can check quantitatively (extended with the three
# frontend-derived ML workloads):
#   interconnect-heavy (slides/reductions): jacobi-2d, pathfinder,
#       canneal/streamcluster (reductions), the attention kernels
#       (online-softmax + dot reductions), ssd_scan (cumsum slide ladder)
#   indexed memory: canneal
#   intensive scalar-core communication: canneal, particlefilter,
#       streamcluster, and both attention kernels (the m/l running-statistics
#       update consumes the reductions' scalar results)
EXPECT = {
    "interconnect": {"jacobi-2d", "pathfinder", "canneal", "streamcluster",
                     "flash_attention", "decode_attention", "ssd_scan"},
    "indexed": {"canneal"},
    "scalar_comm": {"canneal", "particlefilter", "streamcluster",
                    "flash_attention", "decode_attention"},
    # MSHR saturation (sweep_mshr): only indexed-pattern apps are gated by
    # the demand-miss file; unit/strided streams ride the prefetch window
    "mshr_bound": {"canneal"},
}


def shares_all(app_names, mvl=64) -> dict:
    """Static trace shares + simulated busy fractions for many apps at once:
    the timing simulations (including the mshrs=1 saturation point) run as
    one ``simulate_batch`` dispatch set."""
    cfg = eng.VectorEngineConfig(mvl=mvl, lanes=4)
    cfg_m1 = eng.VectorEngineConfig(mvl=mvl, lanes=4, mshrs=1)
    bodies = [tracegen.APPS[a].body(mvl, None) for a in app_names]
    tiles = [b.tile(16) for b in bodies]
    sims = eng.simulate_batch(tiles + tiles, [cfg] * len(tiles)
                              + [cfg_m1] * len(tiles))
    rows = {}
    for i, (app_name, body) in enumerate(zip(app_names, bodies)):
        sim, sim_m1 = sims[i], sims[i + len(bodies)]
        n_vec = np.sum(body.kind != isa.SCALAR_BLOCK)
        manip = np.isin(body.kind, (isa.VSLIDE, isa.VREDUCE)).sum()
        indexed = ((body.kind == isa.VLOAD)
                   & (body.mem_pattern == isa.MEM_INDEXED)).sum()
        dep = body.dep_scalar.sum()
        rows[app_name] = {
            "manip_share": manip / max(n_vec, 1),
            "indexed_share": indexed / max(n_vec, 1),
            "dep_scalar_per_body": float(dep),
            "vmu_busy_frac": sim["vmu_busy"] / sim["time"],
            "lane_busy_frac": sim["lane_busy"] / sim["time"],
            "mshr1_slowdown": sim_m1["time"] / sim["time"],
        }
    return rows


def shares(app_name: str, mvl=64) -> dict:
    return shares_all([app_name], mvl)[app_name]


def mechanistic_all(app_names, mvl=64) -> dict:
    """Cycle-attribution profile per app at the Table-2 config and its
    mshrs=1 ablation: module fractions, top bottleneck, coupling and
    interconnect visible cycles — ``telemetry.profile_app`` rows."""
    cfg = eng.VectorEngineConfig(mvl=mvl, lanes=4)
    cfg_m1 = eng.VectorEngineConfig(mvl=mvl, lanes=4, mshrs=1)
    rows = {}
    for a in app_names:
        r = telemetry.profile_app(a, cfg, tiles=16)
        r1 = telemetry.profile_app(a, cfg_m1, tiles=16)
        rows[a] = {"default": r, "mshr1": r1,
                   "mem_jump": (r1["modules"]["memory"]
                                - r["modules"]["memory"])}
    return rows


def _allowed_tops(diff_row: dict) -> set[str]:
    """Which top bottleneck the *differential* busy fractions admit: any
    module whose unit is busy >50% of the time; if nothing dominates, the
    busier of lanes/memory."""
    allowed = set()
    if diff_row["lane_busy_frac"] > 0.5:
        allowed.add("lanes")
    if diff_row["vmu_busy_frac"] > 0.5:
        allowed.add("memory")
    if not allowed:
        allowed.add("lanes" if diff_row["lane_busy_frac"]
                    >= diff_row["vmu_busy_frac"] else "memory")
    return allowed


def check_consistency(diff: dict, mech: dict) -> list[str]:
    """Cross-check the differential matrix against the mechanistic
    attribution; returns a list of mismatch descriptions (empty = agree)."""
    bad = []
    for a in diff:
        d, m = diff[a], mech[a]
        stalls = m["default"]["stalls"]
        intc = stalls["exec_interconnect"]
        if (intc > 0) != (d["manip_share"] > 0):
            bad.append(f"{a}: interconnect visible={intc:.0f} vs "
                       f"manip_share={d['manip_share']:.2%}")
        dep = stalls["dep_scalar"]
        if (dep > 0) != (a in EXPECT["scalar_comm"]):
            bad.append(f"{a}: dep_scalar visible={dep:.0f} vs "
                       f"scalar_comm={'yes' if a in EXPECT['scalar_comm'] else 'no'}")
        if a in EXPECT["mshr_bound"]:
            if not (m["mem_jump"] > 0.3
                    and m["mshr1"]["top"] == "memory"):
                bad.append(f"{a}: mshr_bound but mem_jump={m['mem_jump']:.3f}"
                           f" top@mshr1={m['mshr1']['top']}")
        elif abs(m["mem_jump"]) > 0.02:
            bad.append(f"{a}: not mshr_bound but mem_jump={m['mem_jump']:.3f}")
        allowed = _allowed_tops(d)
        if m["default"]["top"] not in allowed:
            bad.append(f"{a}: mechanistic top={m['default']['top']} but busy "
                       f"fractions admit {sorted(allowed)}")
    return bad


def main() -> None:
    apps = list(tracegen.APPS)
    rows = shares_all(apps)
    mech = mechanistic_all(apps)
    print(f"{'app':16s} {'manip%':>7s} {'indexed%':>9s} {'dep/body':>9s} "
          f"{'vmu busy':>9s} {'lane busy':>10s} {'mshr1 x':>8s}")
    for a, r in rows.items():
        print(f"{a:16s} {r['manip_share']:7.1%} {r['indexed_share']:9.1%} "
              f"{r['dep_scalar_per_body']:9.0f} {r['vmu_busy_frac']:9.2f} "
              f"{r['lane_busy_frac']:10.2f} {r['mshr1_slowdown']:8.2f}")
    print("\nmechanistic attribution (fraction of runtime per module):")
    print(f"{'app':16s} {'top':10s} "
          + " ".join(f"{m:>7s}" for m in telemetry.MODULES)
          + f" {'mem@mshr1':>10s}")
    for a in apps:
        r = mech[a]["default"]
        print(f"{a:16s} {r['top']:10s} "
              + " ".join(f"{r['modules'][m]:7.3f}" for m in telemetry.MODULES)
              + f" {mech[a]['mshr1']['modules']['memory']:10.3f}")

    ok = True
    for a in EXPECT["interconnect"]:
        ok &= rows[a]["manip_share"] > 0.0
    for a in EXPECT["indexed"]:
        ok &= rows[a]["indexed_share"] > 0.0
    for a in EXPECT["scalar_comm"]:
        ok &= rows[a]["dep_scalar_per_body"] > 0
    for a in EXPECT["mshr_bound"]:
        ok &= rows[a]["mshr1_slowdown"] > 1.2
    for a in set(tracegen.APPS) - EXPECT["mshr_bound"]:
        ok &= rows[a]["mshr1_slowdown"] < 1.05
    # blackscholes/jacobi/pathfinder have no dep-scalar round trips
    for a in set(tracegen.APPS) - EXPECT["scalar_comm"] - {"swaptions"}:
        ok &= rows[a]["dep_scalar_per_body"] == 0
    print("\nTable-2 checkmark matrix:", "CONSISTENT" if ok else "MISMATCH")

    bad = check_consistency(rows, mech)
    if bad:
        print("\nmechanistic <-> differential MISMATCH:")
        for line in bad:
            print(" ", line)
    else:
        print("mechanistic <-> differential: CONSISTENT (10/10 apps)")
    sys.exit(0 if ok and not bad else 1)


if __name__ == "__main__":
    main()
