"""Validate the paper's Table 2: which engine modules each app stresses.

From the timing simulation we extract per-module busy fractions (lanes vs VMU)
and instruction-class shares, and check them against the paper's
checkmark matrix (memory-unit usage, interconnection usage, scalar-core
communication).

    PYTHONPATH=src python benchmarks/module_stress.py
"""
from __future__ import annotations

import numpy as np

from repro.core import engine as eng
from repro.core import isa, tracegen

# paper Table 2 rows we can check quantitatively:
#   interconnect-heavy (slides/reductions): jacobi-2d, pathfinder,
#       canneal/streamcluster/swaptions (reductions)
#   indexed memory: canneal
#   intensive scalar-core communication: canneal, particlefilter, streamcluster
EXPECT = {
    "interconnect": {"jacobi-2d", "pathfinder", "canneal", "streamcluster"},
    "indexed": {"canneal"},
    "scalar_comm": {"canneal", "particlefilter", "streamcluster"},
}


def shares_all(app_names, mvl=64) -> dict:
    """Static trace shares + simulated busy fractions for many apps at once:
    the timing simulations run as one ``simulate_batch`` dispatch set."""
    cfg = eng.VectorEngineConfig(mvl=mvl, lanes=4)
    bodies = [tracegen.APPS[a].body(mvl, None) for a in app_names]
    sims = eng.simulate_batch([b.tile(16) for b in bodies], [cfg])
    rows = {}
    for app_name, body, sim in zip(app_names, bodies, sims):
        n_vec = np.sum(body.kind != isa.SCALAR_BLOCK)
        manip = np.isin(body.kind, (isa.VSLIDE, isa.VREDUCE)).sum()
        indexed = ((body.kind == isa.VLOAD)
                   & (body.mem_pattern == isa.MEM_INDEXED)).sum()
        dep = body.dep_scalar.sum()
        rows[app_name] = {
            "manip_share": manip / max(n_vec, 1),
            "indexed_share": indexed / max(n_vec, 1),
            "dep_scalar_per_body": float(dep),
            "vmu_busy_frac": sim["vmu_busy"] / sim["time"],
            "lane_busy_frac": sim["lane_busy"] / sim["time"],
        }
    return rows


def shares(app_name: str, mvl=64) -> dict:
    return shares_all([app_name], mvl)[app_name]


def main() -> None:
    rows = shares_all(list(tracegen.APPS))
    print(f"{'app':16s} {'manip%':>7s} {'indexed%':>9s} {'dep/body':>9s} "
          f"{'vmu busy':>9s} {'lane busy':>10s}")
    for a, r in rows.items():
        print(f"{a:16s} {r['manip_share']:7.1%} {r['indexed_share']:9.1%} "
              f"{r['dep_scalar_per_body']:9.0f} {r['vmu_busy_frac']:9.2f} "
              f"{r['lane_busy_frac']:10.2f}")
    ok = True
    for a in EXPECT["interconnect"]:
        ok &= rows[a]["manip_share"] > 0.0
    for a in EXPECT["indexed"]:
        ok &= rows[a]["indexed_share"] > 0.0
    for a in EXPECT["scalar_comm"]:
        ok &= rows[a]["dep_scalar_per_body"] > 0
    for a in set(tracegen.APPS) - EXPECT["scalar_comm"] - {"swaptions"}:
        pass  # blackscholes/jacobi/pathfinder have no dep-scalar round trips
    print("\nTable-2 checkmark matrix:", "CONSISTENT" if ok else "MISMATCH")


if __name__ == "__main__":
    main()
