"""Validate the paper's Table 2: which engine modules each app stresses.

From the timing simulation we extract per-module busy fractions (lanes vs VMU)
and instruction-class shares, and check them against the paper's
checkmark matrix (memory-unit usage, interconnection usage, scalar-core
communication).

    PYTHONPATH=src python benchmarks/module_stress.py
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import engine as eng
from repro.core import isa, tracegen

# paper Table 2 rows we can check quantitatively (extended with the three
# frontend-derived ML workloads):
#   interconnect-heavy (slides/reductions): jacobi-2d, pathfinder,
#       canneal/streamcluster/swaptions (reductions), the attention kernels
#       (online-softmax + dot reductions), ssd_scan (cumsum slide ladder)
#   indexed memory: canneal
#   intensive scalar-core communication: canneal, particlefilter,
#       streamcluster, and both attention kernels (the m/l running-statistics
#       update consumes the reductions' scalar results)
EXPECT = {
    "interconnect": {"jacobi-2d", "pathfinder", "canneal", "streamcluster",
                     "flash_attention", "decode_attention", "ssd_scan"},
    "indexed": {"canneal"},
    "scalar_comm": {"canneal", "particlefilter", "streamcluster",
                    "flash_attention", "decode_attention"},
    # MSHR saturation (sweep_mshr): only indexed-pattern apps are gated by
    # the demand-miss file; unit/strided streams ride the prefetch window
    "mshr_bound": {"canneal"},
}


def shares_all(app_names, mvl=64) -> dict:
    """Static trace shares + simulated busy fractions for many apps at once:
    the timing simulations (including the mshrs=1 saturation point) run as
    one ``simulate_batch`` dispatch set."""
    cfg = eng.VectorEngineConfig(mvl=mvl, lanes=4)
    cfg_m1 = eng.VectorEngineConfig(mvl=mvl, lanes=4, mshrs=1)
    bodies = [tracegen.APPS[a].body(mvl, None) for a in app_names]
    tiles = [b.tile(16) for b in bodies]
    sims = eng.simulate_batch(tiles + tiles, [cfg] * len(tiles)
                              + [cfg_m1] * len(tiles))
    rows = {}
    for i, (app_name, body) in enumerate(zip(app_names, bodies)):
        sim, sim_m1 = sims[i], sims[i + len(bodies)]
        n_vec = np.sum(body.kind != isa.SCALAR_BLOCK)
        manip = np.isin(body.kind, (isa.VSLIDE, isa.VREDUCE)).sum()
        indexed = ((body.kind == isa.VLOAD)
                   & (body.mem_pattern == isa.MEM_INDEXED)).sum()
        dep = body.dep_scalar.sum()
        rows[app_name] = {
            "manip_share": manip / max(n_vec, 1),
            "indexed_share": indexed / max(n_vec, 1),
            "dep_scalar_per_body": float(dep),
            "vmu_busy_frac": sim["vmu_busy"] / sim["time"],
            "lane_busy_frac": sim["lane_busy"] / sim["time"],
            "mshr1_slowdown": sim_m1["time"] / sim["time"],
        }
    return rows


def shares(app_name: str, mvl=64) -> dict:
    return shares_all([app_name], mvl)[app_name]


def main() -> None:
    rows = shares_all(list(tracegen.APPS))
    print(f"{'app':16s} {'manip%':>7s} {'indexed%':>9s} {'dep/body':>9s} "
          f"{'vmu busy':>9s} {'lane busy':>10s} {'mshr1 x':>8s}")
    for a, r in rows.items():
        print(f"{a:16s} {r['manip_share']:7.1%} {r['indexed_share']:9.1%} "
              f"{r['dep_scalar_per_body']:9.0f} {r['vmu_busy_frac']:9.2f} "
              f"{r['lane_busy_frac']:10.2f} {r['mshr1_slowdown']:8.2f}")
    ok = True
    for a in EXPECT["interconnect"]:
        ok &= rows[a]["manip_share"] > 0.0
    for a in EXPECT["indexed"]:
        ok &= rows[a]["indexed_share"] > 0.0
    for a in EXPECT["scalar_comm"]:
        ok &= rows[a]["dep_scalar_per_body"] > 0
    for a in EXPECT["mshr_bound"]:
        ok &= rows[a]["mshr1_slowdown"] > 1.2
    for a in set(tracegen.APPS) - EXPECT["mshr_bound"]:
        ok &= rows[a]["mshr1_slowdown"] < 1.05
    # blackscholes/jacobi/pathfinder have no dep-scalar round trips
    for a in set(tracegen.APPS) - EXPECT["scalar_comm"] - {"swaptions"}:
        ok &= rows[a]["dep_scalar_per_body"] == 0
    print("\nTable-2 checkmark matrix:", "CONSISTENT" if ok else "MISMATCH")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
