"""Serving benchmark: the simulation service under Poisson arrivals.

Drives ``repro.serve.sim_service.SimService`` with a seeded Poisson request
stream (apps x sampled DSE configs) in realtime — sleeping out the true
inter-arrival gaps — and reports the acceptance quantities: sustained
throughput (requests/sec), p50/p99 latency, cache hit / coalesce / shed
counts and steady-state recompiles, then repeats the identical stream
against the persisted cache, which must answer >= 99 % of requests as hits
with bitwise-identical times.

Standalone: ``python benchmarks/serve_bench.py [--quick] [--cache PATH]``;
``benchmarks/run.py --serve`` embeds the same study in ``BENCH_pr10.json``.
"""
from __future__ import annotations

import time


def _workload(quick: bool, seed: int):
    from repro.configs import vector_engine as vcfg
    from repro.serve.sim_service import poisson_arrivals
    if quick:
        apps = ("blackscholes", "canneal")
        cfgs = tuple(vcfg.SPACE_SMOKE.sample(16, seed=seed + 1))
        n, rate = 96, 400.0
    else:
        # the full stream mixes hand-coded, jaxpr-derived and RVV-assembly
        # trace sources — the service must coalesce across all of them
        apps = ("blackscholes", "canneal", "ssd_scan", "pathfinder:asm")
        cfgs = tuple(vcfg.SPACE_QUICK.sample(32, seed=seed + 1))
        n, rate = 400, 200.0
    return poisson_arrivals(n, rate, apps, cfgs, seed=seed), apps, cfgs, rate


def serve_study(quick: bool = False, cache_path: str | None = None,
                seed: int = 0, realtime: bool = True,
                max_batch: int = 16):
    """Run the two-pass serving study; returns (csv rows, bench-json dict)."""
    from repro.core import dse
    from repro.serve.sim_service import SimService, run_workload

    arrivals, apps, cfgs, rate = _workload(quick, seed)
    svc = SimService(cache=dse.ResultCache(cache_path), max_batch=max_batch)
    t0 = time.perf_counter()
    n_warmed = svc.prewarm()
    prewarm_s = time.perf_counter() - t0
    rep1 = run_workload(svc, arrivals, realtime=realtime)

    # repeat pass: fresh service, cache re-read from disk when persistent
    svc2 = SimService(cache=dse.ResultCache(cache_path) if cache_path
                      else svc.cache, max_batch=max_batch)
    rep2 = run_workload(svc2, arrivals, realtime=realtime)
    r1 = sorted(rep1.results, key=lambda r: r.uid)
    r2 = sorted(rep2.results, key=lambda r: r.uid)
    bitwise = (len(r1) == len(r2) and
               all(a.steady_ns == b.steady_ns and a.app == b.app
                   for a, b in zip(r1, r2)))
    ok = (rep1.recompiles == 0 and rep2.hit_fraction >= 0.99 and bitwise
          and rep1.shed == 0)

    label = "quick" if quick else "full"
    rows = [
        (f"serve_{label}_throughput", rep1.wall_s * 1e6,
         f"{rep1.throughput_rps:.1f}req_s|n={rep1.n}|rate={rate:g}Hz"),
        (f"serve_{label}_latency", 0.0,
         f"p50={rep1.p50_ms:.2f}ms|p99={rep1.p99_ms:.2f}ms"
         f"|mean={rep1.mean_ms:.2f}ms"),
        (f"serve_{label}_batching", 0.0,
         f"dispatched={rep1.dispatched}|coalesced={rep1.coalesced}"
         f"|batches={rep1.batches}|recompiles={rep1.recompiles}"
         f"|prewarmed={n_warmed}"),
        (f"serve_{label}_repeat", rep2.wall_s * 1e6,
         f"hit_fraction={rep2.hit_fraction:.3f}"
         f"|throughput={rep2.throughput_rps:.1f}req_s"
         f"|{'bitwise' if bitwise else 'DIVERGED'}"
         f"|{'ok' if ok else 'FAIL'}"),
    ]
    bench = {
        "mode": label, "n": len(arrivals), "rate_hz": rate,
        "apps": list(apps), "n_configs": len(cfgs), "seed": seed,
        "realtime": realtime, "max_batch": max_batch,
        "prewarm_s": prewarm_s, "prewarmed_buckets": n_warmed,
        "pass1": rep1.to_dict(), "repeat": rep2.to_dict(),
        "bitwise_repeat": bitwise, "ok": ok,
        "cache_path": cache_path,
    }
    return rows, bench


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--cache", default=None, help="JSONL ResultCache path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-realtime", action="store_true",
                    help="replay arrivals back-to-back (deterministic/fast)")
    args = ap.parse_args(argv)
    rows, bench = serve_study(quick=args.quick, cache_path=args.cache,
                              seed=args.seed,
                              realtime=not args.no_realtime)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return 0 if bench["ok"] else 1


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    raise SystemExit(main())
