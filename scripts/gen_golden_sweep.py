"""Golden sweep table (24 configs x 10 apps + 10 :asm variants): gen/check.

Two modes:

* default — regenerate ``tests/golden_sweep.json``.  Run after an
  *intentional* recalibration of the timing model, then review the diff.
* ``--check`` — regenerate **in memory** and diff against the checked-in
  table with a per-cell tolerance report (app, cell, got, want, rel err),
  exiting non-zero on drift.  This is what ``tests/test_golden_sweep.py``
  wraps: a drifted table fails with the exact offending cells, not a silent
  full-file mismatch.

    PYTHONPATH=src python scripts/gen_golden_sweep.py [--check] [--rtol R]
"""
from __future__ import annotations

import json
import os

from repro.core import suite

OUT = os.path.join(os.path.dirname(__file__), "..", "tests",
                   "golden_sweep.json")
RTOL = 1e-2  # generous vs float32 platform jitter, tight vs real drift


def _payload() -> dict:
    """All 10 registered apps plus the 10 RVV-assembly-sourced variants
    (trace source: the generated src/repro/asm corpus via repro.core.rvv)
    — 480 cells, up from 408 when the corpus was the hand-written RiVec
    seven (PR 7 generates all ten from the jaxpr kernel specs).  The
    ``:asm`` cells pin the *decoder* end to end: a decode regression that
    survives the crossval mixes still shows up as a speedup drift here."""
    from repro.core import tracegen
    apps = sorted(tracegen.APPS) + list(tracegen.ASM_APPS)
    table = suite.sweep_all(apps)
    return {app: {f"{m}x{l}": round(s, 6) for (m, l), s in grid.items()}
            for app, grid in table.items()}


def diff_report(got: dict, golden: dict, rtol: float = RTOL) -> list[str]:
    """Per-cell tolerance report between two payloads (empty == clean)."""
    report: list[str] = []
    for app in sorted(set(golden) - set(got)):
        report.append(f"{app}: in golden table but not in sweep")
    for app in sorted(set(got) - set(golden)):
        report.append(f"{app}: swept but missing from golden table "
                      f"(regenerate: PYTHONPATH=src python "
                      f"scripts/gen_golden_sweep.py)")
    for app in sorted(set(got) & set(golden)):
        cells_got, cells_want = got[app], golden[app]
        for cell in sorted(set(cells_want) - set(cells_got)):
            report.append(f"{app} {cell}: missing from sweep")
        for cell in sorted(set(cells_got) - set(cells_want)):
            report.append(f"{app} {cell}: not in golden table")
        for cell in sorted(set(cells_got) & set(cells_want)):
            g, w = cells_got[cell], cells_want[cell]
            rel = abs(g - w) / max(abs(w), 1e-12)
            if rel > rtol:
                report.append(f"{app} {cell}: got={g:.6f} want={w:.6f} "
                              f"rel={rel:.2e} > rtol={rtol:g}")
    return report


def check(rtol: float = RTOL, golden_path: str = OUT) -> list[str]:
    """Regenerate the sweep in memory and diff against the golden file.
    Returns the per-cell report; never writes anything."""
    with open(golden_path) as f:
        golden = json.load(f)
    return diff_report(_payload(), golden, rtol=rtol)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="diff the regenerated sweep against the golden "
                         "table instead of writing it")
    ap.add_argument("--rtol", type=float, default=RTOL)
    args = ap.parse_args(argv)
    if args.check:
        report = check(rtol=args.rtol)
        for line in report:
            print(line)
        print(f"golden check: {len(report)} problem(s) at "
              f"rtol={args.rtol:g}")
        return 1 if report else 0
    payload = _payload()
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)}: "
          f"{sum(len(g) for g in payload.values())} cells")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
