"""Regenerate tests/golden_sweep.json (the 24-config x 7-app speedup table).

Run after an *intentional* recalibration of the timing model, then review the
diff — tests/test_golden_sweep.py pins every cell so silent drift fails CI.

    PYTHONPATH=src python scripts/gen_golden_sweep.py
"""
from __future__ import annotations

import json
import os

from repro.core import suite

OUT = os.path.join(os.path.dirname(__file__), "..", "tests",
                   "golden_sweep.json")


def main() -> None:
    table = suite.sweep_all()
    payload = {app: {f"{m}x{l}": round(s, 6) for (m, l), s in grid.items()}
               for app, grid in table.items()}
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.normpath(OUT)}: "
          f"{sum(len(g) for g in payload.values())} cells")


if __name__ == "__main__":
    main()
