#!/usr/bin/env bash
# One-command regression gate: tier-1 tests + the quick benchmark smoke.
#   scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== quick benchmark smoke =="
python benchmarks/run.py --quick
