#!/usr/bin/env bash
# One-command regression gate: tier-1 tests + docs gate + quick benchmark.
#   scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== docs gate: doctests =="
python -m pytest --doctest-modules -q \
  src/repro/core/memory.py src/repro/core/suite.py

echo "== docs gate: README quickstart snippet =="
# extract the FIRST ```python fenced block from the README and execute it,
# so the documented example cannot rot
snippet="$(mktemp --suffix=.py)"
trap 'rm -f "$snippet"' EXIT
awk '/^```python/{if(!done){f=1};next} /^```/{if(f){f=0;done=1}} f' \
  README.md > "$snippet"
python "$snippet"

echo "== frontend cross-validation gate =="
# derived (jaxpr-lowered) bodies vs hand-coded tracegen bodies: exact
# kind/FU/pattern/element/scalar mixes, steady-state time within 5%
python -m repro.core.frontend

echo "== quick benchmark smoke =="
python benchmarks/run.py --quick
