#!/usr/bin/env bash
# One-command regression gate: tier-1 tests + docs gate + quick benchmark.
#   scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# -p no:randomly: the property tier (tests/test_properties.py) must run with
# its fixed seeds — the hypothesis shim seeds its own RNG and real hypothesis
# runs derandomized, but pytest-randomly (if ever installed) would still
# reorder/reseed; disabling an absent plugin is a no-op.
python -m pytest -x -q -p no:randomly

echo "== docs gate: doctests =="
python -m pytest --doctest-modules -q -p no:randomly \
  src/repro/core/memory.py src/repro/core/suite.py src/repro/core/dse.py \
  src/repro/core/codegen.py src/repro/serve/sim_service.py \
  src/repro/core/surrogate.py src/repro/core/search.py \
  src/repro/core/scalar_pipeline.py src/repro/core/telemetry.py

echo "== docs gate: README snippets =="
# extract EVERY ```python fenced block from the README and execute them in
# order as one script, so no documented example can rot
snippet="$(mktemp --suffix=.py)"
trap 'rm -f "$snippet"' EXIT
awk '/^```python/{f=1;next} /^```/{f=0} f' README.md > "$snippet"
python "$snippet"

echo "== scalar-scorecard gate =="
# the event-based scalar-pipeline baseline vs all 11 paper §5 anchors, plus
# batched-vs-sequential bitwise equivalence, knob monotonicity and the
# physical-CPI floor (no app's baseline may imply scalar CPI < 0.5)
python -m repro.core.scalar_pipeline --check

echo "== frontend cross-validation gate =="
# derived (jaxpr-lowered) bodies vs hand-coded tracegen bodies: exact
# kind/FU/pattern/element/scalar mixes, steady-state time within 5%
python -m repro.core.frontend

echo "== rvv-crossval gate =="
# the RVV assembly corpus (src/repro/asm) decoded back through
# repro.core.rvv vs the hand-coded bodies, at EVERY mvl in {8..256}:
# static mixes exact, steady-state time within 5%, decoder-derived chunk
# counts against the characterized closed forms, body invariants clean
python -m repro.core.rvv --check-all

echo "== codegen-roundtrip gate =="
# the closed loop: every app with a jaxpr kernel= spec is emitted to RVV
# assembly (repro.core.codegen) and decoded back (repro.core.rvv) at EVERY
# mvl in {8..256} — the decoded chunk body must be bitwise
# fingerprint-equal to the direct jaxpr lowering, with the characterized
# chunk count and clean trace invariants
python -m repro.core.codegen --check-all

echo "== corpus-drift gate =="
# the checked-in src/repro/asm/*.s corpus must byte-match what the
# emitter produces from the kernel specs (no hand edits, no stale files)
python scripts/gen_rvv_corpus.py --check

echo "== dse-smoke gate =="
# 64-point space, single device: explore twice through a fresh on-disk
# cache; the second pass must be 100% hits with a bitwise-identical
# Pareto frontier (the DSE determinism contract)
dse_tmp="$(mktemp -d)"
trap 'rm -f "$snippet"; rm -rf "$dse_tmp"' EXIT
python -m repro.core.dse --space smoke --cache "$dse_tmp/cache.jsonl" --smoke

echo "== surrogate-smoke gate =="
# learned-cost-model search: train the MLP surrogate on a 64-point explore,
# search the 18k-point SPACE_10K; every frontier point must be backed by an
# exact cached engine result (runtime re-derives bitwise) and repeat runs —
# exhaustive-scoring AND evolutionary modes — must be bitwise-identical
python -m repro.core.search --smoke

echo "== serve-smoke gate =="
# simulation service: short Poisson request stream through a fresh on-disk
# cache — prewarmed pass must not recompile at steady state; the repeated
# identical stream must be >=99% ResultCache hits with bitwise-identical
# times (the serving determinism contract)
serve_tmp="$(mktemp -d)"
trap 'rm -f "$snippet"; rm -rf "$dse_tmp" "$serve_tmp"' EXIT
python -m repro.serve.sim_service --smoke --cache "$serve_tmp/cache.jsonl"

echo "== profile-smoke gate =="
# mechanistic cycle attribution: event-sum identity (attributed cycles
# reconstruct total runtime) on all 10 apps x 2 configs, collect_stats
# timing bitwise-identical to the default scan, timeline JSON validity,
# latency-histogram sanity
python -m repro.core.telemetry --smoke

echo "== module-stress gate =="
# paper Table 2 two independent ways: the differential checkmark matrix
# (static shares + knob ablation) must agree with the mechanistic
# cycle attribution for all 10 apps — any mismatch prints the per-module
# breakdown and fails
python benchmarks/module_stress.py

echo "== quick benchmark smoke =="
python benchmarks/run.py --quick
