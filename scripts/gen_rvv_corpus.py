"""Generate the RVV assembly corpus (``src/repro/asm/*.s``).

Every app carrying a jaxpr ``kernel=`` spec — the seven RiVec apps and the
three ML workloads — ships a generated RVV v1.0 spelling of its loop body.
Since PR 7 the instruction bodies are not hand-maintained: each file is
``repro.core.codegen.emit_app(app)``, the code generator that lowers the
jaxpr kernel spec and spells the resulting vector-IR records back as
assembly (per-VL dispatch, ``.chunk``/``.stream`` directives, exact
fractional trip counts).  The generated files are checked in; regenerate
after changing a kernel spec, the frontend lowering, or the emitter:

    PYTHONPATH=src python scripts/gen_rvv_corpus.py

The committed corpus must byte-match the regenerator (the ci.sh
``corpus-drift`` gate)::

    PYTHONPATH=src python scripts/gen_rvv_corpus.py --check

and the decoded corpus is held to the other frontends by two CI gates:
``python -m repro.core.rvv --check-all`` (decoded vs hand-coded bodies at
every MVL of the paper grid) and ``python -m repro.core.codegen
--check-all`` (decoded vs jaxpr lowering, bitwise).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.core import codegen, tracegen

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "src", "repro", "asm")


def corpus_apps() -> list[str]:
    """Every registered app with both a kernel= spec and an asm= entry."""
    return [a for a in sorted(tracegen.APPS)
            if tracegen.APPS[a].kernel is not None and tracegen.APPS[a].asm]


def generate() -> dict[str, str]:
    """``{filename: text}`` for the whole corpus."""
    return {tracegen.APPS[a].asm: codegen.emit_app(a) for a in corpus_apps()}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="(Re)generate src/repro/asm/*.s from the jaxpr kernel "
                    "specs via repro.core.codegen.")
    ap.add_argument("--check", action="store_true",
                    help="verify the committed corpus byte-matches the "
                         "regenerator output instead of writing (the ci.sh "
                         "corpus-drift gate)")
    args = ap.parse_args(argv)
    os.makedirs(OUT_DIR, exist_ok=True)
    drift = []
    for fname, text in generate().items():
        path = os.path.join(OUT_DIR, fname)
        if args.check:
            on_disk = None
            if os.path.exists(path):
                with open(path) as f:
                    on_disk = f.read()
            if on_disk != text:
                drift.append(fname)
                print(f"DRIFT: {fname} does not match emit_app output")
            else:
                print(f"ok: {fname}")
        else:
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text.splitlines())} lines)")
    if args.check:
        verdict = "IN SYNC" if not drift else f"{len(drift)} file(s) DRIFTED"
        print(f"corpus vs emitter: {verdict}")
        return 0 if not drift else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
