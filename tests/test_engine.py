"""Property tests on the vector-engine timing model (hypothesis)."""
import dataclasses

import numpy as np
import pytest

try:  # hypothesis is optional (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.testing.hypothesis_shim import given, settings, strategies as st

from repro.core import engine as eng
from repro.core import isa, tracegen


def _body(mvl=64):
    return tracegen.APPS["blackscholes"].body(mvl, None).tile(3)


def _time(cfg, body=None):
    return eng.simulate(body if body is not None else _body(cfg.mvl), cfg)["time"]


cfg_st = st.builds(
    eng.VectorEngineConfig,
    mvl=st.sampled_from([8, 16, 64, 256]),
    lanes=st.sampled_from([1, 2, 4, 8]),
    phys_regs=st.sampled_from([34, 40, 64]),
    queue_entries=st.sampled_from([4, 16]),
    ooo_issue=st.booleans(),
    vrf_read_ports=st.sampled_from([1, 3]),
)


@settings(max_examples=20, deadline=None)
@given(cfg_st)
def test_positive_and_deterministic(cfg):
    t1, t2 = _time(cfg), _time(cfg)
    assert t1 > 0 and t1 == t2


@settings(max_examples=15, deadline=None)
@given(cfg_st)
def test_more_lanes_never_slower(cfg):
    if cfg.lanes >= 8:
        return
    t1 = _time(cfg)
    t2 = _time(dataclasses.replace(cfg, lanes=cfg.lanes * 2))
    assert t2 <= t1 * 1.001, (t1, t2)


@settings(max_examples=15, deadline=None)
@given(cfg_st)
def test_ooo_not_slower_than_inorder(cfg):
    a = _time(dataclasses.replace(cfg, ooo_issue=False))
    b = _time(dataclasses.replace(cfg, ooo_issue=True))
    assert b <= a * 1.001


@settings(max_examples=15, deadline=None)
@given(cfg_st)
def test_more_read_ports_never_slower(cfg):
    if cfg.vrf_read_ports != 1:
        return
    a = _time(cfg)
    b = _time(dataclasses.replace(cfg, vrf_read_ports=3))
    assert b <= a * 1.001


@settings(max_examples=10, deadline=None)
@given(cfg_st)
def test_bigger_queues_never_slower(cfg):
    if cfg.queue_entries != 4:
        return
    a = _time(cfg)
    b = _time(dataclasses.replace(cfg, queue_entries=16))
    assert b <= a * 1.001


def test_startup_time_effect():
    """Paper §5.1: start-up time hurts small MVL relatively more."""
    body8 = tracegen.APPS["blackscholes"].body(8, None)
    body256 = tracegen.APPS["blackscholes"].body(256, None)
    cfg1 = eng.VectorEngineConfig(mvl=8, lanes=1, vrf_read_ports=1)
    cfg3 = eng.VectorEngineConfig(mvl=8, lanes=1, vrf_read_ports=3)
    rel8 = eng.steady_state_time(body8, cfg1) / eng.steady_state_time(body8, cfg3)
    cfg1b = dataclasses.replace(cfg1, mvl=256)
    cfg3b = dataclasses.replace(cfg3, mvl=256)
    rel256 = eng.steady_state_time(body256, cfg1b) / eng.steady_state_time(body256, cfg3b)
    assert rel8 > rel256  # extra read ports matter more at short VL


def test_crossbar_reductions_not_slower_than_ring():
    recs = []
    for i in range(16):
        recs.append(isa.vreduce(256, src1=i % 8, dst=20))
    tr = isa.Trace.from_records(recs)
    ring = eng.VectorEngineConfig(mvl=256, lanes=8, interconnect="ring")
    xbar = eng.VectorEngineConfig(mvl=256, lanes=8, interconnect="crossbar")
    assert eng.simulate(tr, xbar)["time"] <= eng.simulate(tr, ring)["time"]


def test_vmu_serializes_memory():
    """Two loads cannot overlap in the VMU (paper §3.2.5)."""
    one = isa.Trace.from_records([isa.vload(256, dst=0)])
    two = isa.Trace.from_records([isa.vload(256, dst=0), isa.vload(256, dst=1)])
    cfg = eng.VectorEngineConfig(mvl=256, lanes=8)
    t1 = eng.simulate(one, cfg)["time"]
    t2 = eng.simulate(two, cfg)["time"]
    assert t2 >= t1 * 1.6


def test_dep_scalar_stalls():
    base = [isa.varith(64, src1=0, src2=1, dst=2),
            isa.vmask_scalar(64, src1=2),
            isa.scalar_block(100)]
    dep = [isa.varith(64, src1=0, src2=1, dst=2),
           isa.vmask_scalar(64, src1=2),
           isa.scalar_block(100, dep_scalar=True)]
    cfg = eng.VectorEngineConfig(mvl=64, lanes=1)
    t_base = eng.simulate(isa.Trace.from_records(base * 8), cfg)["time"]
    t_dep = eng.simulate(isa.Trace.from_records(dep * 8), cfg)["time"]
    assert t_dep >= t_base


def test_config_rejects_capacities_beyond_ring():
    """engine.MAX_RING used to silently wrap (corrupting every result) when
    a capacity exceeded it; construction now fails loudly."""
    for kw in ({"rob_entries": eng.MAX_RING + 1},
               {"queue_entries": eng.MAX_RING + 1},
               {"phys_regs": 32 + eng.MAX_RING + 1}):
        with pytest.raises(ValueError, match="MAX_RING"):
            eng.VectorEngineConfig(**kw)
    with pytest.raises(ValueError, match="phys_regs"):
        eng.VectorEngineConfig(phys_regs=32)
    # boundary values are legal
    eng.VectorEngineConfig(rob_entries=eng.MAX_RING,
                           queue_entries=eng.MAX_RING,
                           phys_regs=32 + eng.MAX_RING)
