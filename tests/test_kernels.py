"""Per-kernel allclose vs pure-jnp oracles; shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

K = jax.random.key


@pytest.mark.parametrize("n,block", [(2048, 512), (8192, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_blackscholes(n, block, dtype):
    spot = jax.random.uniform(K(0), (n,), dtype, 10, 100)
    strike = jax.random.uniform(K(1), (n,), dtype, 10, 100)
    rate = jnp.full((n,), 0.05, dtype)
    vol = jax.random.uniform(K(2), (n,), dtype, 0.1, 0.6)
    t = jax.random.uniform(K(3), (n,), dtype, 0.2, 2.0)
    calls = (jax.random.uniform(K(4), (n,)) > 0.5).astype(jnp.int32)
    got = ops.blackscholes(spot, strike, rate, vol, t, calls, block=block)
    want = ref.blackscholes(spot, strike, rate, vol, t, calls)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape,rpb", [((66, 128), 64), ((130, 256), 32)])
def test_jacobi2d(shape, rpb):
    a = jax.random.normal(K(5), shape)
    np.testing.assert_allclose(ops.jacobi2d_step(a, rows_per_block=rpb),
                               ref.jacobi2d(a), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("R,C", [(10, 128), (40, 512)])
def test_pathfinder(R, C):
    wall = jax.random.uniform(K(6), (R, C), minval=0, maxval=10)
    np.testing.assert_allclose(ops.pathfinder(wall), ref.pathfinder(wall),
                               rtol=1e-6)


@pytest.mark.parametrize("m,n,d,bm,bn", [(256, 128, 64, 128, 128),
                                         (512, 256, 128, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_streamcluster(m, n, d, bm, bn, dtype):
    p = jax.random.normal(K(7), (m, d), dtype)
    c = jax.random.normal(K(8), (n, d), dtype)
    tol = 1e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(ops.streamcluster_dist(p, c, bm=bm, bn=bn),
                               ref.streamcluster_dist(p, c), rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [2048, 8192])
def test_swaptions_cumnorminv(n):
    u = jax.random.uniform(K(9), (n,), minval=1e-5, maxval=1 - 1e-5)
    np.testing.assert_allclose(ops.cum_normal_inv(u, block=1024),
                               ref.cum_normal_inv(u), rtol=1e-5, atol=1e-6)
    # sanity vs scipy-style inverse: cndf(inv(u)) ~= u
    x = ops.cum_normal_inv(u, block=1024)
    back = 0.5 * (1 + jax.lax.erf(x / np.sqrt(2)))
    np.testing.assert_allclose(back, u, atol=5e-4)


@pytest.mark.parametrize("N,B,F", [(512, 256, 24), (1024, 512, 8)])
def test_canneal(N, B, F):
    locs = jax.random.randint(K(10), (N, 2), 0, 1000).astype(jnp.float32)
    fan = jax.random.randint(K(11), (B, F), -1, N)
    ca = jax.random.randint(K(12), (B, 2), 0, 1000).astype(jnp.float32)
    cb = jax.random.randint(K(13), (B, 2), 0, 1000).astype(jnp.float32)
    oa, ob = ops.canneal_swap_cost(locs, fan, ca, cb)
    ra, rb = ref.canneal_swap_cost(locs, fan, ca, cb)
    np.testing.assert_allclose(oa, ra, rtol=1e-6)
    np.testing.assert_allclose(ob, rb, rtol=1e-6)


@pytest.mark.parametrize("n,m", [(4096, 512), (2048, 256)])
def test_particlefilter(n, m):
    cdf = jnp.sort(jax.random.uniform(K(14), (n,)))
    u = jax.random.uniform(K(15), (m,))
    np.testing.assert_array_equal(ops.particlefilter_findindex(cdf, u),
                                  ref.particlefilter_findindex(cdf, u))


@pytest.mark.parametrize("S,bq,bk", [(256, 128, 128), (512, 128, 256)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(S, bq, bk, causal, dtype):
    B, H, D = 2, 2, 64
    q = jax.random.normal(K(16), (B, S, H, D), dtype)
    k = jax.random.normal(K(17), (B, S, H, D), dtype)
    v = jax.random.normal(K(18), (B, S, H, D), dtype)
    got = ops.flash_attention(q, k, v, bq=bq, bk=bk, causal=causal)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(got.astype(jnp.float32), want.astype(jnp.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("S,bk,kv_len", [(256, 64, 100), (512, 128, 512)])
def test_decode_attention(S, bk, kv_len):
    B, H, D = 2, 4, 64
    q = jax.random.normal(K(19), (B, H, D))
    k = jax.random.normal(K(20), (B, S, H, D))
    v = jax.random.normal(K(21), (B, S, H, D))
    got = ops.decode_attention(q, k, v, jnp.full((B,), kv_len), bk=bk)
    want = jax.vmap(lambda qq, kk, vv: ref.decode_attention(
        qq[None], kk[None], vv[None], kv_len)[0])(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("S,chunk", [(256, 64), (512, 128)])
def test_ssd_scan(S, chunk):
    b, H, P, N = 2, 4, 16, 32
    x = jax.random.normal(K(22), (b, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(K(23), (b, S, H)))
    A = -jnp.exp(jax.random.normal(K(24), (H,)) * 0.3)
    B_ = jax.random.normal(K(25), (b, S, N)) * 0.5
    C_ = jax.random.normal(K(26), (b, S, N)) * 0.5
    got = ops.ssd_scan(x, dt, A, B_, C_, chunk=chunk)
    want = ref.ssd_scan(x, dt, A, B_, C_, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=4e-3, atol=4e-3)
