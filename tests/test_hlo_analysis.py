"""HLO-text analyzer unit tests on synthetic modules."""
from repro.core import hlo_analysis as H

SYNTH = """\
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %d = f32[8,8]{1,0} dot(%ar, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %d)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_trip_count_applied():
    a = H.analyze(SYNTH)
    # dot: 2*8*8*8 = 1024 flops, x12 trips
    assert abs(a["flops"] - 12 * (1024 + 64)) <= 12 * 80, a["flops"]
    # all-reduce: 2 * 256B * (3/4) = 384B per trip
    assert abs(a["ici_bytes"] - 12 * 384) < 1, a["ici_bytes"]
    assert a["static_collective_count"] == 1


def test_tuple_shapes_with_index_comments():
    txt = SYNTH.replace(
        "(s32[], f32[8,8]) while", "(s32[], /*index=1*/f32[8,8]) while")
    a = H.analyze(txt)
    assert a["ici_bytes"] > 0  # while still parsed despite '=' in comment


def test_group_size_iota_format():
    txt = SYNTH.replace("replica_groups={{0,1,2,3}}", "replica_groups=[2,2]<=[4]")
    a = H.analyze(txt)
    # group size 2 -> 2*256*(1/2) = 256B per trip
    assert abs(a["ici_bytes"] - 12 * 256) < 1, a["ici_bytes"]


def test_slicing_ops_count_window_not_operand():
    txt = """\
HloModule t

ENTRY %main (x: f32[1024,64], i: s32[]) -> f32[1,64] {
  %x = f32[1024,64]{1,0} parameter(0)
  %i = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %ds = f32[1,64]{1,0} dynamic-slice(%x, %i, %z), dynamic_slice_sizes={1,64}
}
"""
    a = H.analyze(txt)
    assert a["hbm_bytes"] == 2 * 64 * 4  # slice read + write, not 1024x64
