"""Distribution correctness on fake multi-device meshes (subprocess: the
device count must be set before jax initializes, and the main pytest process
must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 16) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_moe_shardmap_matches_local():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import moe as M
        from repro.distributed.sharding import use_mesh
        from repro.launch.mesh import make_compat_mesh
        for arch in ("dbrx-132b", "granite-moe-3b-a800m"):
            cfg = get_config(arch).smoke()
            k = jax.random.key
            p = {"router": jax.random.normal(k(0),(cfg.d_model,cfg.num_experts))*0.1,
                 "w1": jax.random.normal(k(1),(cfg.num_experts,cfg.d_model,cfg.d_ff))*0.05,
                 "w3": jax.random.normal(k(2),(cfg.num_experts,cfg.d_model,cfg.d_ff))*0.05,
                 "w2": jax.random.normal(k(3),(cfg.num_experts,cfg.d_ff,cfg.d_model))*0.05}
            h = jax.random.normal(k(4), (4, 8, cfg.d_model))
            ref, _ = M.moe_fwd(p, h, cfg)
            mesh = make_compat_mesh((2,4),("data","model"))
            with use_mesh(mesh):
                out, _ = jax.jit(lambda p,h: M.moe_fwd(p,h,cfg))(p, h)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
            print(arch, "ok")
    """, devices=8))


def test_flash_decode_shardmap_matches_local():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build
        from repro.distributed.sharding import use_mesh
        from repro.launch.mesh import make_compat_mesh
        cfg = get_config("llama3-8b").smoke().scaled(cache_dtype="float32")
        m = build(cfg)
        params = m.init(jax.random.key(0))
        batch = {"tokens": jax.random.randint(jax.random.key(1),(4,16),0,cfg.vocab_size)}
        logits, cache = m.prefill(params, batch, max_seq=32)
        tok = jnp.argmax(logits[:,-1],-1)[:,None].astype(jnp.int32)
        l_ref, c_ref = m.decode_step(params, cache, tok, jnp.int32(16))
        mesh = make_compat_mesh((2,4),("data","model"))
        with use_mesh(mesh):
            l_sm, c_sm = jax.jit(lambda p,c,t: m.decode_step(p,c,t,jnp.int32(16)))(params, cache, tok)
        np.testing.assert_allclose(np.asarray(l_sm), np.asarray(l_ref), rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(c_sm["k"]), np.asarray(c_ref["k"]), rtol=1e-5, atol=1e-5)
        print("flash decode ok")
    """, devices=8))


def test_sharded_train_step_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.models import build
        from repro.train import trainstep, optimizer as opt
        from repro.launch.mesh import make_host_mesh
        cfg = get_config("qwen2.5-3b").smoke()
        model = build(cfg)
        shape = InputShape("tiny", 16, 8, "train")
        params = model.init(jax.random.key(0))
        state = opt.init(params)
        batch = {"tokens": jax.random.randint(jax.random.key(1),(8,16),0,cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.key(2),(8,16),0,cfg.vocab_size)}
        # single device reference
        fn0, _, _, _ = trainstep.build_train_step(model, shape, make_host_mesh(data=1, model=1), microbatches=1)
        p0, s0, m0 = jax.jit(fn0)(params, state, batch)
        # 2x4 mesh, 2 microbatches
        mesh = make_host_mesh(data=2, model=4)
        fn, in_sh, out_sh, donate = trainstep.build_train_step(model, shape, mesh, microbatches=2)
        p1, s1, m1 = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)(params, state, batch)
        assert abs(float(m0["loss"]) - float(m1["loss"])) < 5e-3, (m0["loss"], m1["loss"])
        for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
            np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-3)
        print("sharded train ok, loss", float(m1["loss"]))
    """, devices=8))


def test_pipeline_parallel_matches_sequential():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply
        from repro.launch.mesh import make_compat_mesh
        mesh = make_compat_mesh((2,), ("pod",))
        stages = 2
        def fn_stage(p, x):
            return jnp.tanh(x @ p["w"])
        k = jax.random.key
        params = {"w": jax.random.normal(k(0), (stages, 16, 16)) * 0.5}
        x = jax.random.normal(k(1), (4, 8, 16))  # 4 microbatches
        # sequential reference
        ref = x
        for s in range(stages):
            ref = jax.vmap(lambda xm: fn_stage({"w": params["w"][s]}, xm))(ref)
        got = pipeline_apply(fn_stage, params, x, mesh, stages=stages)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)
        print("pipeline ok")
    """, devices=2))


def test_small_dryrun_lower_compile():
    """End-to-end mini dry-run: lower+compile a reduced arch on an 8-device
    mesh, run the HLO analyzer, check the roofline terms are positive."""
    print(_run("""
        import jax, json
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.models import api as mapi
        from repro.train import trainstep
        from repro.core import hlo_analysis
        from repro.launch.mesh import make_host_mesh
        cfg = get_config("llama3-8b").smoke()
        model = mapi.build(cfg)
        shape = InputShape("tiny", 32, 8, "train")
        mesh = make_host_mesh(data=2, model=4)
        fn, in_sh, out_sh, donate = trainstep.build_train_step(model, shape, mesh)
        args = (model.param_structs(), trainstep.opt_structs(model.param_structs()),
                mapi.input_specs(cfg, shape))
        co = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate).lower(*args).compile()
        a = hlo_analysis.analyze(co.as_text())
        assert a["flops"] > 0 and a["hbm_bytes"] > 0
        mem = co.memory_analysis()
        assert mem.temp_size_in_bytes >= 0
        print("mini dryrun ok", json.dumps({k: a[k] for k in ("flops","hbm_bytes","ici_bytes")}))
    """, devices=8))
