"""Property-based test tier: randomized traces vs. engine invariants.

The DSE engine (repro.core.dse) trusts the timing model on *thousands* of
configs no golden table covers, so these properties stress it the way a
design-space sweep will: random ``isa.TraceBuilder`` traces and random
configs, asserting the invariants a designer reads off a Pareto frontier —

  * more lanes never slow a trace down (absent interconnect-hop kinds),
  * a single MSHR never speeds one up,
  * the batched path is the sequential path (bitwise),
  * NOP padding is timing-neutral (bitwise).

Runs under real ``hypothesis`` when installed (derandomized: CI needs fixed
seeds) and under ``repro.testing.hypothesis_shim`` (seeded sampling)
otherwise.  Trace lengths are held to a small fixed set so the sequential
``simulate`` path compiles a handful of executables, not one per example.
"""
import numpy as np

try:  # hypothesis is optional (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    from repro.testing.hypothesis_shim import given, settings, strategies as st

from repro.core import engine as eng
from repro.core import isa

N_OPS = 24          # fixed record count -> one compiled sequential scan
VLS = (8, 16, 64, 256)
FOOTPRINTS = (8.0, 64.0, 2048.0)

# Kinds whose execution cost is non-increasing in `lanes`.  VREDUCE and
# VMASK_SCALAR are excluded *by the model*: their lane-interconnect hop count
# (ring: lanes-1, crossbar: ceil(log2(lanes))) grows with the lane count, so
# lane monotonicity is not an invariant for them (the paper's §3.2.6 point).
LANE_SAFE_KINDS = ("arith", "load", "store", "slide", "move", "scalar")
ALL_KINDS = LANE_SAFE_KINDS + ("reduce", "mask")


def random_trace(seed: int, kinds=ALL_KINDS, n_ops: int = N_OPS) -> isa.Trace:
    """A random but well-formed trace through the shared TraceBuilder API."""
    rng = np.random.RandomState(seed)
    b = isa.TraceBuilder()
    for _ in range(n_ops):
        k = kinds[rng.randint(len(kinds))]
        vl = int(VLS[rng.randint(len(VLS))])
        r = lambda: int(rng.randint(8))
        if k == "arith":
            b.arith(vl, fu=int(rng.randint(isa.N_FU_CLASSES)),
                    src1=r(), src2=r(), dst=r())
        elif k == "load":
            b.load(vl, dst=r(), pattern=int(rng.randint(3)),
                   footprint_kb=float(FOOTPRINTS[rng.randint(3)]))
        elif k == "store":
            b.store(vl, src1=r(), pattern=int(rng.randint(3)),
                    footprint_kb=float(FOOTPRINTS[rng.randint(3)]))
        elif k == "slide":
            b.slide(vl, src1=r(), dst=r())
        elif k == "move":
            b.move(vl, src1=r(), dst=r())
        elif k == "reduce":
            b.reduce(vl, src1=r(), dst=r(),
                     fu=int(rng.randint(isa.N_FU_CLASSES)))
        elif k == "mask":
            b.mask_to_scalar(vl, src1=r())
        else:
            b.scalar(int(rng.randint(1, 40)),
                     fu=int(rng.randint(isa.N_FU_CLASSES)),
                     dep_scalar=bool(rng.randint(2)))
    return b.build()


def random_config(seed: int, **overrides) -> eng.VectorEngineConfig:
    rng = np.random.RandomState(seed + 777)
    kv = dict(
        mvl=int((8, 64, 256)[rng.randint(3)]),
        lanes=int((1, 2, 4, 8)[rng.randint(4)]),
        ooo_issue=bool(rng.randint(2)),
        interconnect=("ring", "crossbar")[rng.randint(2)],
        queue_entries=int((8, 16)[rng.randint(2)]),
        l2_kb=int((256, 1024)[rng.randint(2)]),
        mshrs=int((1, 16)[rng.randint(2)]),
    )
    kv.update(overrides)
    return eng.VectorEngineConfig(**kv)


seeds = st.integers(min_value=0, max_value=10 ** 9)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seeds)
def test_more_lanes_never_slower(seed):
    """Doubling `lanes` is monotonically non-increasing in simulated time for
    traces without interconnect-hop kinds: every per-instruction execution
    term is non-increasing in lanes and the scan recurrence is a monotone
    (max/+) composition, so total time inherits it."""
    tr = random_trace(seed, kinds=LANE_SAFE_KINDS)
    times = [eng.simulate(tr, random_config(seed, lanes=l))["time"]
             for l in (1, 2, 4, 8)]
    for slow, fast in zip(times, times[1:]):
        assert fast <= slow * (1 + 1e-5), times


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seeds)
def test_single_mshr_never_faster(seed):
    """`mshrs=1` serializes every demand (gather) miss: simulated time is
    non-increasing in the MSHR count, on any trace (regular streams ride the
    prefetch window and are simply unaffected)."""
    tr = random_trace(seed)
    times = [eng.simulate(tr, random_config(seed, mshrs=m))["time"]
             for m in (1, 4, 16)]
    for slow, fast in zip(times, times[1:]):
        assert fast <= slow * (1 + 1e-5), times


@settings(max_examples=8, deadline=None, derandomize=True)
@given(seeds)
def test_batch_equals_sequential_bitwise(seed):
    """simulate_batch is sequential simulate, bitwise, on random (trace,
    config) pairs — the scan core is shared and NOP padding is neutral, so
    the DSE engine's batched dispatches are exactly the classic path."""
    traces = [random_trace(seed + i) for i in range(3)]
    cfgs = [random_config(seed + i) for i in range(3)]
    for row, tr, cfg in zip(eng.simulate_batch(traces, cfgs), traces, cfgs):
        assert row == eng.simulate(tr, cfg)


@settings(max_examples=8, deadline=None, derandomize=True)
@given(seeds)
def test_nop_padding_invariance(seed):
    """Appending NOPs to a random trace changes no metric, bitwise —
    the property that makes length bucketing and warmup fusion exact."""
    tr = random_trace(seed)
    cfg = random_config(seed)
    base = eng.simulate(tr, cfg)
    for extra in (1, 8, 40):
        assert eng.simulate(tr.pad_to(N_OPS + extra), cfg) == base


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seeds)
def test_steady_state_lane_monotonicity(seed):
    """The DSE's actual objective — steady-state loop-body time — is also
    non-increasing in lanes for interconnect-free bodies (it is a difference
    of two monotone totals over the same tiles; slack can shift between
    warmup and measurement windows, hence the small tolerance)."""
    body = random_trace(seed, kinds=LANE_SAFE_KINDS, n_ops=12)
    times = eng.steady_state_time_batch(
        [body] * 4, [random_config(seed, lanes=l) for l in (1, 2, 4, 8)],
        warmup=2, measure=4)
    for slow, fast in zip(times, times[1:]):
        assert fast <= slow * 1.01 + 1e-6, times
