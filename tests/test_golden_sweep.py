"""Golden regression on the full paper sweep (Table 10 grid x 7 apps).

The 8 anchor points in test_suite_timing.py catch gross miscalibration; this
pins all 168 cells of the batched sweep against a checked-in snapshot so
*silent* drift — an engine refactor nudging timings, a tracegen constant edit
— fails loudly.  After an intentional recalibration, regenerate with
``PYTHONPATH=src python scripts/gen_golden_sweep.py`` and review the diff.
"""
import json
import os

from repro.core import suite

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_sweep.json")
RTOL = 1e-2  # generous vs float32 platform jitter, tight vs real drift


def test_sweep_matches_golden_table():
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = suite.sweep_all()
    assert set(got) == set(golden)
    bad = []
    for app, grid in got.items():
        assert len(grid) == len(golden[app]) == 24
        for (m, l), s in grid.items():
            want = golden[app][f"{m}x{l}"]
            if abs(s - want) > RTOL * abs(want):
                bad.append((app, m, l, s, want))
    assert not bad, f"{len(bad)} drifted cells, first 5: {bad[:5]}"
