"""Golden regression on the full paper sweep (Table 10 grid x 10 apps).

The 8 anchor points in test_suite_timing.py catch gross miscalibration; this
pins all 240 cells of the batched sweep against a checked-in snapshot so
*silent* drift — an engine refactor nudging timings, a tracegen constant edit
— fails loudly.

The comparison is the generator's own ``--check`` mode
(``scripts/gen_golden_sweep.py``), so a failure prints the per-cell
tolerance report (app, cell, got, want, rel err) instead of a bare file
mismatch.  After an intentional recalibration, regenerate with
``PYTHONPATH=src python scripts/gen_golden_sweep.py`` and review the diff.
"""
import os
import sys

_SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "scripts")
sys.path.insert(0, _SCRIPTS)
import gen_golden_sweep  # noqa: E402  (the generator doubles as the checker)


def test_sweep_matches_golden_table():
    report = gen_golden_sweep.check()
    assert not report, "golden sweep drift:\n" + "\n".join(report)
