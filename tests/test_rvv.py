"""RVV assembly frontend: decoder units, vsetvli/strip-mine semantics,
LMUL register-group validation, the corpus cross-validation contract, and
the fuzz property tier (any successfully decoded stream satisfies the isa
trace invariants)."""
import os

import numpy as np
import pytest

try:  # hypothesis is optional (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    from repro.testing.hypothesis_shim import given, settings, strategies as st

from repro.core import engine as eng
from repro.core import isa, rvv, suite, tracegen

SAXPY = os.path.join(os.path.dirname(__file__), "..", "examples", "rvv",
                     "saxpy.s")


def _dec(text, mvl=64, **kw):
    return rvv.decode(text, mvl, **kw)


PRE = ("    li a0, 64\n"
       "    vsetvli t0, a0, e64, m1, ta, ma\n")


# ------------------------------------------------------------------ units

def test_unit_strided_indexed_patterns_and_stream_footprints():
    d = _dec(
        "    .stream table 3072.0\n"
        "    .stream out 8.0\n"
        + PRE +
        "    la a1, table\n"
        "    la a2, out\n"
        "    vle64.v v1, (a1)\n"
        "    vlse64.v v2, (a1), t1\n"
        "    vluxei64.v v3, (a1), v1\n"
        "    vse64.v v2, (a2)\n"
        "    ret\n")
    tr = d.trace
    loads = tr.kind == isa.VLOAD
    assert list(tr.mem_pattern[loads]) == [isa.MEM_UNIT, isa.MEM_STRIDED,
                                           isa.MEM_INDEXED]
    assert all(tr.footprint_kb[loads] == np.float32(3072.0))
    assert tr.footprint_kb[tr.kind == isa.VSTORE][0] == np.float32(8.0)
    # the gather consumes its index vector as a register source
    g = np.flatnonzero(loads)[2]
    assert tr.n_src[g] == 1 and tr.src1[g] == 1


def test_vsetvli_sew_lmul_vlmax():
    # VLEN = 8*64 = 512 bits; e32 m4 -> VLMAX = 512/32*4 = 64
    d = _dec("    li a0, 1000000\n"
             "    vsetvli t0, a0, e32, m4, ta, ma\n"
             "    vmv.v.i v4, 0\n"
             "    ret\n", mvl=8)
    assert d.trace.vl[0] == 64
    # mf2 halves it: 512/64/2 = 4
    d = _dec("    li a0, 1000000\n"
             "    vsetvli t0, a0, e64, mf2, ta, ma\n"
             "    vmv.v.i v4, 0\n"
             "    ret\n", mvl=8)
    assert d.trace.vl[0] == 4
    # AVL below VLMAX wins
    d = _dec("    li a0, 5\n"
             "    vsetvli t0, a0, e64, m1, ta, ma\n"
             "    vmv.v.i v4, 0\n"
             "    ret\n", mvl=64)
    assert d.trace.vl[0] == 5


def test_lmul_register_group_alignment():
    with pytest.raises(rvv.RvvError, match="aligned to the LMUL"):
        _dec("    li a0, 8\n"
             "    vsetvli t0, a0, e64, m2, ta, ma\n"
             "    vmv.v.i v3, 0\n"      # v3 not 2-aligned under m2
             "    ret\n")
    with pytest.raises(rvv.RvvError, match="must be 2-aligned"):
        _dec(PRE + "    vmv.v.i v1, 0\n"
                   "    vmv2r.v v3, v1\n    ret\n")


def test_lmul_group_aliasing_defines_whole_group():
    # writing v2 under m2 defines v2+v3; reading v3 under m1 then works
    d = _dec("    li a0, 8\n"
             "    vsetvli t0, a0, e64, m2, ta, ma\n"
             "    vmv.v.i v2, 0\n"
             "    vsetvli t0, a0, e64, m1, ta, ma\n"
             "    vadd.vv v4, v3, v2\n"
             "    ret\n")
    assert isa.kind_histogram(d.trace)[isa.VARITH] == 1


def test_mask_registers_are_single_regs_under_lmul():
    # comparisons write a single mask register (any number is legal under
    # LMUL>1); mask-logical ops read/write single registers too
    d = _dec("    li a0, 8\n"
             "    vsetvli t0, a0, e64, m2, ta, ma\n"
             "    vmv.v.i v2, 0\n"
             "    vmseq.vv v5, v2, v2\n"      # odd mask dest: legal
             "    vmnot.m v7, v5\n"
             "    ret\n")
    assert isa.kind_histogram(d.trace)[isa.VARITH] == 2


def test_typoed_scalar_operand_is_loud():
    with pytest.raises(rvv.RvvError, match="unknown scalar operand"):
        _dec(PRE + "    addi t4, t44, 1\n    ret\n")


def test_whole_register_move_at_narrow_sew_validates():
    d = _dec("    li a0, 4\n"
             "    vsetvli t0, a0, e32, m1, ta, ma\n"
             "    vmv.v.i v1, 0\n"
             "    vmv1r.v v2, v1\n"           # 2*mvl elements at e32
             "    ret\n", mvl=64)
    assert d.trace.vl.max() == 128 and d.validate() == []


def test_use_before_def_is_loud():
    with pytest.raises(rvv.RvvError, match="read before any write"):
        _dec(PRE + "    vadd.vv v1, v2, v3\n    ret\n")


def test_vector_before_vsetvli_is_loud():
    with pytest.raises(rvv.RvvError, match="before any vsetvli"):
        _dec("    vmv.v.i v1, 0\n    ret\n")


def test_scalar_coalescing_dep_and_bookkeeping_folding():
    d = _dec(PRE +
             "    vmv.v.i v1, 0\n"
             "    vcpop.m t3, v1\n"
             "    add s2, s2, t3\n"      # consumes the hot mask result
             "    addi s3, s3, 1\n"      # plain modeled scalar work
             "    li t4, 77\n"           # bookkeeping: folds away
             "    addi t4, t4, 1\n"      # still known -> folds away
             "    vmv.v.v v2, v1\n"
             "    ret\n")
    tr = d.trace
    blocks = np.flatnonzero(tr.kind == isa.SCALAR_BLOCK)
    assert len(blocks) == 1
    assert tr.scalar_count[blocks[0]] == 2       # add + addi, li/addi folded
    assert bool(tr.dep_scalar[blocks[0]])
    assert isa.kind_histogram(tr)[isa.VMASK_SCALAR] == 1


def test_mask_v0t_adds_a_register_read():
    d = _dec(PRE +
             "    vmv.v.i v0, 0\n"
             "    vmv.v.i v1, 0\n"
             "    vadd.vv v2, v1, v1, v0.t\n"
             "    ret\n")
    a = np.flatnonzero(d.trace.kind == isa.VARITH)[0]
    assert d.trace.n_src[a] == 3
    with pytest.raises(rvv.RvvError, match="v0 read"):
        _dec(PRE + "    vmv.v.i v1, 0\n"
                   "    vadd.vv v2, v1, v1, v0.t\n    ret\n")


def test_whole_register_move_ignores_vl():
    d = _dec("    li a0, 4\n"
             "    vsetvli t0, a0, e64, m1, ta, ma\n"
             "    vmv.v.i v1, 0\n"
             "    vmv1r.v v2, v1\n"
             "    ret\n", mvl=128)
    tr = d.trace
    moves = np.flatnonzero(tr.kind == isa.VMOVE)
    assert tr.vl[moves[0]] == 4          # vmv.v.i at VL
    assert tr.vl[moves[1]] == 128        # vmv1r.v at VLEN/SEW, not VL


def test_fma_keeps_accumulator_dependency():
    d = _dec(PRE +
             "    vmv.v.i v1, 0\n"
             "    vmv.v.i v2, 0\n"
             "    vfmacc.vv v2, v1, v1\n"
             "    ret\n")
    a = np.flatnonzero(d.trace.kind == isa.VARITH)[0]
    assert d.trace.n_src[a] == 3 and d.trace.src2[a] == 2


def test_unknown_mnemonics_and_calls_are_loud():
    with pytest.raises(rvv.RvvError, match="no vector-IR mapping"):
        _dec(PRE + "    vwadd.vv v2, v4, v6\n    ret\n")
    with pytest.raises(rvv.RvvError, match="not decodable"):
        _dec(PRE + "    call exp\n    ret\n")
    with pytest.raises(rvv.RvvError, match="unsupported mnemonic"):
        _dec(PRE + "    frobnicate s1, s2\n    ret\n")


def test_branch_on_unknown_value_is_loud():
    with pytest.raises(rvv.RvvError, match="branch on unknown"):
        _dec(PRE + "loop:\n    addi s1, s1, 1\n    bnez s1, loop\n    ret\n")


# ------------------------------------------- strip-mine / chunk semantics

STRIP = ("    .stream x 64.0\n"
         "    li a0, {avl}\n"
         "    la a1, x\n"
         "loop:\n"
         "    vsetvli t0, a0, e64, m1, ta, ma\n"
         "    vle64.v v0, (a1)\n"
         "    vfadd.vv v1, v0, v0\n"
         "    vse64.v v1, (a1)\n"
         "    sub a0, a0, t0\n"
         "    bnez a0, loop\n"
         "    ret\n")


def test_strip_mine_total_elements_invariant():
    """ISSUE acceptance: decoding the same .s at different mvl yields the
    same per-element work (total elements invariant), with exact partial
    tail VLs when the AVL does not divide."""
    for avl in (1024, 1000, 37):
        totals = []
        for mvl in (8, 16, 32, 64, 128, 256):
            tr = _dec(STRIP.format(avl=avl), mvl).trace
            vec = tr.kind != isa.SCALAR_BLOCK
            totals.append(int(tr.vl[vec].sum()))
            tail = avl % min(mvl, avl)
            if tail:
                assert tr.vl[-1] == tail
        assert len(set(totals)) == 1, (avl, totals)
        assert totals[0] == 3 * avl      # load + add + store per element


def test_chunk_marker_emits_one_body_with_trip_count():
    text = STRIP.replace("loop:", ".chunk\nloop:").format(avl=4096)
    for mvl in (8, 64, 256):
        d = _dec(text, mvl)
        assert len(d.trace) == 3
        assert d.chunks == 4096 / mvl
        # tiled body == the fully expanded loop, record for record
        full = _dec(STRIP.format(avl=4096), mvl, expand=True).trace
        assert isa.trace_fingerprint(d.trace.tile(int(d.chunks))) == \
            isa.trace_fingerprint(full)


def test_counted_chunk_loop_trip_count():
    d = _dec("    li a0, 64\n"
             "    li a3, 12345\n"
             "    vsetvli t0, a0, e64, m1, ta, ma\n"
             "    vmv.v.i v1, 0\n"
             ".chunk\n"
             "body:\n"
             "    vfadd.vv v2, v1, v1\n"
             "    addi a3, a3, -1\n"
             "    bnez a3, body\n"
             "    ret\n")
    assert d.chunks == 12345.0
    assert len(d.trace) == 1 and len(d.prologue) == 1


def test_saxpy_decodes_and_simulates_end_to_end():
    """ISSUE acceptance: a kernel not in the suite produces a simulatable
    trace end-to-end."""
    cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
    d = rvv.decode_file(SAXPY, 64, cfg)
    assert d.validate() == []
    assert len(d.trace) > 0 and d.chunks == 1.0
    out = eng.simulate(d.full_trace, cfg)
    assert np.isfinite(out["time"]) and out["time"] > 0
    # the same file at a different MVL does the same element work
    d8 = rvv.decode_file(SAXPY, 8)
    vec = lambda t: t.vl[t.kind != isa.SCALAR_BLOCK].sum()
    assert int(vec(d8.full_trace)) == int(vec(d.full_trace))


# ------------------------------------------------- corpus cross-validation

def test_corpus_crossval_reference_configs():
    """ISSUE acceptance (test-tier half; ci.sh runs the full per-MVL grid):
    the generated corpus decodes to bodies that match the hand-coded traces
    — static mixes exact, steady-state time within 5% — for all ten apps
    (the RiVec seven plus the codegen-emitted ML workloads)."""
    cfgs = [eng.VectorEngineConfig(mvl=64, lanes=4),
            eng.VectorEngineConfig(mvl=16, lanes=2)]
    reports = rvv.cross_validate_all(cfgs=cfgs)
    corpus = {a for a in tracegen.APPS if tracegen.APPS[a].asm}
    assert {r.app for r in reports} == corpus
    assert corpus >= set(tracegen.RIVEC_APPS) and len(corpus) == 10
    bad = [(r.app, r.cfg_label, r.time_rel_err) for r in reports if not r.ok]
    assert not bad, bad
    # The ML workloads decode BITWISE-identical to their suite bodies (both
    # sides are the jaxpr lowering).  The RiVec seven differ from the
    # hand-coded bodies in register naming/source structure — those are held
    # bitwise to the jaxpr lowering by the codegen round-trip gate instead
    # (test_generated_corpus_round_trips / --check-all).
    by_app = {}
    for r in reports:
        by_app.setdefault(r.app, []).append(r.fingerprint_eq)
    exact = {a for a, v in by_app.items() if all(v)}
    assert exact >= {"flash_attention", "decode_attention", "ssd_scan"}


def test_asm_chunk_counts_match_characterized_closed_forms():
    for app in (a for a in tracegen.APPS if tracegen.APPS[a].asm):
        for mvl in (8, 64, 256):
            cfg = eng.VectorEngineConfig(mvl=mvl, lanes=4)
            eff = suite.effective_mvl(app, cfg)
            got = rvv.asm_chunks(app, eff, cfg)
            want = tracegen.APPS[app].chunks(eff)
            assert abs(got - want) / want < 1e-6, (app, mvl, got, want)


def test_corpus_bodies_pass_isa_invariants():
    """Satellite: every decoded corpus body satisfies the trace invariants
    (registers in range, vl <= mvl, no dangling sources given the
    prologue definitions)."""
    for app in (a for a in tracegen.APPS if tracegen.APPS[a].asm):
        cfg = eng.VectorEngineConfig(mvl=64, lanes=4)
        d = rvv.decode_app(app, suite.effective_mvl(app, cfg), cfg)
        assert d.validate() == [], app


def test_asm_variant_rides_the_batched_sweep():
    table = suite.sweep_all(["blackscholes", "blackscholes:asm",
                             "canneal", "canneal:asm",
                             "flash_attention", "flash_attention:asm"],
                            mvls=(8, 64), lanes=(1, 8))
    for cell in table["blackscholes"]:
        # bitwise-identical body (the emitted corpus IS the jaxpr lowering)
        # + identical chunk model -> identical speedup
        assert table["flash_attention:asm"][cell] == \
            table["flash_attention"][cell]
        # the RiVec decoded bodies differ from the hand-coded suite bodies
        # only in register/source structure: speedups track within crossval
        # timing tolerance
        for app in ("blackscholes", "canneal"):
            rel = abs(table[f"{app}:asm"][cell] - table[app][cell]) \
                / table[app][cell]
            assert rel < 0.05, (app, cell, rel)


# ------------------------------------------------------ fuzz property tier

_FUZZ_OPS = ("vadd.vv", "vfmul.vv", "vfdiv.vv", "vmin.vv", "vfpow.vv")


def _random_stream(seed: int) -> tuple[str, int]:
    """A random *well-formed* RVV stream: every vector source is defined
    before use (the decoder rejects anything else, which the loud-error
    units pin), mixing loads/stores/arith/slides/reductions/masks/scalar
    work at random AVLs."""
    rng = np.random.RandomState(seed)
    mvl = int((8, 16, 64, 256)[rng.randint(4)])
    avl = int(rng.randint(2, 300))
    lines = ["    .stream sa 64.0", "    .stream sb 2048.0",
             "    la a1, sa", "    la a2, sb",
             f"    li a0, {avl}",
             "    vsetvli t0, a0, e64, m1, ta, ma"]
    defined = []
    for _ in range(int(rng.randint(1, 4))):
        r = int(rng.randint(32))
        lines.append(f"    vmv.v.i v{r}, 0")
        defined.append(r)
    for _ in range(int(rng.randint(5, 40))):
        k = rng.randint(8)
        pick = lambda: defined[rng.randint(len(defined))]
        d = int(rng.randint(32))
        if k == 0:
            lines.append(f"    vle64.v v{d}, (a1)")
            defined.append(d)
        elif k == 1:
            lines.append(f"    vluxei64.v v{d}, (a2), v{pick()}")
            defined.append(d)
        elif k == 2:
            lines.append(f"    vse64.v v{pick()}, (a2)")
        elif k == 3:
            op = _FUZZ_OPS[rng.randint(len(_FUZZ_OPS))]
            lines.append(f"    {op} v{d}, v{pick()}, v{pick()}")
            defined.append(d)
        elif k == 4:
            lines.append(f"    vslide1down.vx v{d}, v{pick()}, zero")
            defined.append(d)
        elif k == 5:
            lines.append(f"    vredsum.vs v{d}, v{pick()}, v{pick()}")
            defined.append(d)
        elif k == 6:
            lines.append(f"    vcpop.m t3, v{pick()}")
            lines.append("    add s2, s2, t3")
        else:
            lines.append(f"    addi s{int(rng.randint(2, 12))}, s1, 1")
    lines.append("    ret")
    return "\n".join(lines), mvl


seeds = st.integers(min_value=0, max_value=10 ** 9)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(seeds)
def test_fuzzed_streams_decode_to_invariant_traces(seed):
    """Satellite property: any successfully decoded stream yields a trace
    that passes the isa invariants — registers in [0, 32) (after LMUL
    grouping), vl <= mvl, and no source read before its first write."""
    text, mvl = _random_stream(seed)
    d = rvv.decode(text, mvl)
    tr = d.full_trace
    assert len(tr) > 0
    problems = isa.validate_trace(tr, mvl)
    assert problems == [], (problems, text)
    vec = tr.kind != isa.SCALAR_BLOCK
    assert tr.vl[vec].max() <= mvl
