"""Serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Request, ServeEngine, serve_batch


def test_serve_batch_greedy():
    cfg = get_config("llama3-8b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    prompts = [np.arange(5, dtype=np.int32), np.arange(3, 8, dtype=np.int32)]
    outs = serve_batch(model, params, prompts, max_new_tokens=4, max_seq=16)
    assert len(outs) == 2 and all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.padded_vocab for o in outs for t in o)


def test_engine_continuous_batching():
    cfg = get_config("qwen2.5-3b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_size=2, max_seq=16)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert all(r.done and len(r.out_tokens) == 3 for r in done)
