"""Serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Request, ServeEngine, serve_batch


@pytest.fixture(scope="module")
def qwen_smoke():
    cfg = get_config("qwen2.5-3b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def test_serve_batch_greedy():
    cfg = get_config("llama3-8b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    prompts = [np.arange(5, dtype=np.int32), np.arange(3, 8, dtype=np.int32)]
    outs = serve_batch(model, params, prompts, max_new_tokens=4, max_seq=16)
    assert len(outs) == 2 and all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.padded_vocab for o in outs for t in o)


def test_engine_continuous_batching(qwen_smoke):
    model, params = qwen_smoke
    eng = ServeEngine(model, params, batch_size=2, max_seq=16)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert all(r.done and len(r.out_tokens) == 3 for r in done)


def test_engine_mixed_budgets_stop_at_own_limit(qwen_smoke):
    # the pre-fix wave barrier decoded max(max_new_tokens) lock-step for the
    # whole wave; each sequence must now stop exactly at its own budget
    model, params = qwen_smoke
    budgets = [1, 5, 3, 2]
    eng = ServeEngine(model, params, batch_size=2, max_seq=32)
    for i, b in enumerate(budgets):
        eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                           max_new_tokens=b))
    done = eng.run()
    assert {r.uid: len(r.out_tokens) for r in done} == \
        {i: b for i, b in enumerate(budgets)}
    # never decodes past the aggregate budget (no duplicate padded work)
    assert eng.decode_steps <= sum(budgets)
    assert eng.prefill_rounds <= len(budgets)


def test_engine_backfill_is_fifo(qwen_smoke):
    model, params = qwen_smoke
    eng = ServeEngine(model, params, batch_size=2, max_seq=16)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=np.arange(3, dtype=np.int32) + i,
                           max_new_tokens=2))
    done = eng.run()
    assert [r.uid for r in done] == [0, 1, 2, 3]


def test_engine_underfull_batch_pads_with_dead_slots(qwen_smoke):
    # fewer requests than slots: padding is shape-only, never surfaces as
    # extra finished requests or extra rounds
    model, params = qwen_smoke
    eng = ServeEngine(model, params, batch_size=4, max_seq=16)
    eng.submit(Request(uid=0, prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=3))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    assert eng.prefill_rounds == 1 and eng.decode_steps == 2


def test_engine_single_round_matches_serve_batch(qwen_smoke):
    # homogeneous budgets with batch_size == n requests is exactly one
    # serve_batch call — tokens must agree bitwise
    model, params = qwen_smoke
    prompts = [np.arange(5, dtype=np.int32),
               np.arange(3, 8, dtype=np.int32)]
    want = serve_batch(model, params, prompts, max_new_tokens=4, max_seq=16)
    eng = ServeEngine(model, params, batch_size=2, max_seq=16)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert [r.out_tokens for r in done] == want
